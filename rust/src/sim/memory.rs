//! Memory system model: off-chip DRAM (bandwidth + energy per byte),
//! on-chip SRAM buffers, and the FUM (Fetch-Upon-Mask) accounting that
//! turns the block mask into saved DRAM traffic (paper §IV-A: "If the
//! mask value is 0 ... the corresponding K values will not be fetched").

use crate::tensor::Tensor;

use super::config::SimConfig;

/// Accumulated traffic of one pipeline stage / head / layer.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Traffic {
    pub dram_bytes: f64,
    pub sram_bytes: f64,
}

impl Traffic {
    pub fn add(&mut self, o: Traffic) {
        self.dram_bytes += o.dram_bytes;
        self.sram_bytes += o.sram_bytes;
    }

    /// Cycles to stream the DRAM traffic at the configured bandwidth
    /// (SRAM is assumed to keep pace with the PEs).
    pub fn dram_cycles(&self, cfg: &SimConfig) -> f64 {
        self.dram_bytes / cfg.dram_bytes_per_cycle
    }

    pub fn energy_pj(&self, cfg: &SimConfig) -> f64 {
        self.dram_bytes * cfg.e_dram_pj_per_byte
            + self.sram_bytes * cfg.e_sram_pj_per_byte
    }
}

/// Traffic of fetching a full `[rows, cols]` operand from DRAM once
/// (plus writing it through SRAM).
pub fn fetch_full(cfg: &SimConfig, rows: usize, cols: usize) -> Traffic {
    let bytes = rows as f64 * cols as f64 * cfg.bytes_per_elem();
    Traffic { dram_bytes: bytes, sram_bytes: bytes }
}

/// Does a `[l, d_head]` operand with `field_bytes` per element fit in
/// the core's SRAM (leaving half the buffer for scores/accumulators)?
pub fn operand_resident(cfg: &SimConfig, l: usize, d_head: usize,
                        field_bytes: f64) -> bool {
    (l * d_head) as f64 * field_bytes <= cfg.sram_bytes / 2.0
}

/// K-operand traffic for one head's score pass, honoring SRAM capacity.
///
/// * Resident: K's field is fetched **once**; with a mask, only the
///   union of block-columns that appear in any kept block.
/// * Streamed (the long-sequence regime): K is re-streamed per Q
///   block-row and FUM skips masked blocks at stream rate — traffic is
///   proportional to *kept blocks*, which is where the paper's memory
///   saving comes from.
///
/// `kept_blocks`/`total_blocks` describe the mask; `union_cols` is the
/// number of block-columns touched by at least one kept block.
pub fn k_operand_traffic(
    cfg: &SimConfig,
    l: usize,
    d_head: usize,
    field_bytes: f64,
    kept_blocks: f64,
    total_blocks: f64,
    union_cols: f64,
) -> Traffic {
    let b = cfg.block as f64;
    let bytes = if operand_resident(cfg, l, d_head, field_bytes) {
        union_cols * b * d_head as f64 * field_bytes
    } else {
        // one stream pass per Q block-row; each kept block pulls its
        // K tile. Normalize so the dense case equals
        // (l/b) passes × union — i.e. kept_blocks/total × full stream.
        let full_stream = (l as f64 / b) * (l as f64) * d_head as f64
            * field_bytes;
        full_stream * (kept_blocks / total_blocks.max(1.0))
    };
    Traffic { dram_bytes: bytes, sram_bytes: bytes }
}

/// FUM fetch for the fractional K (and Q) fields: only the block rows /
/// columns that appear in at least one kept block are read.
///
/// `mask` is the `[l/b, l/b]` keep mask. Returns (q_block_rows_touched,
/// k_block_cols_touched) and the resulting traffic for fetching the
/// fraction fields of Q rows and K rows actually needed.
pub fn fum_fetch(
    cfg: &SimConfig,
    mask: &Tensor,
    d_head: usize,
) -> (usize, usize, Traffic) {
    let (nbr, nbc) = (mask.rows(), mask.cols());
    let mut row_touched = vec![false; nbr];
    let mut col_touched = vec![false; nbc];
    for i in 0..nbr {
        for j in 0..nbc {
            if mask.at(i, j) > 0.0 {
                row_touched[i] = true;
                col_touched[j] = true;
            }
        }
    }
    let rt = row_touched.iter().filter(|t| **t).count();
    let ct = col_touched.iter().filter(|t| **t).count();
    let b = cfg.block as f64;
    // Fraction fields are frac_field/8 bytes per element.
    let frac_bytes = cfg.widths.frac_field as f64 / 8.0;
    let bytes =
        (rt as f64 + ct as f64) * b * d_head as f64 * frac_bytes;
    (
        rt,
        ct,
        Traffic { dram_bytes: bytes, sram_bytes: bytes },
    )
}

/// Dense-equivalent fraction fetch (what FUM saves against): all of
/// FQ and FK.
pub fn frac_fetch_dense(cfg: &SimConfig, l: usize, d_head: usize) -> Traffic {
    let frac_bytes = cfg.widths.frac_field as f64 / 8.0;
    let bytes = 2.0 * l as f64 * d_head as f64 * frac_bytes;
    Traffic { dram_bytes: bytes, sram_bytes: bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, prop_assert};
    use crate::util::rng::SplitMix64;

    #[test]
    fn full_fetch_bytes() {
        let cfg = SimConfig::edge(); // 2 bytes/elem
        let t = fetch_full(&cfg, 64, 32);
        assert_eq!(t.dram_bytes, 64.0 * 32.0 * 2.0);
        assert!(t.energy_pj(&cfg) > 0.0);
    }

    #[test]
    fn fum_empty_mask_fetches_nothing() {
        let cfg = SimConfig::edge();
        let mask = Tensor::zeros(&[8, 8]);
        let (rt, ct, t) = fum_fetch(&cfg, &mask, 32);
        assert_eq!((rt, ct), (0, 0));
        assert_eq!(t.dram_bytes, 0.0);
    }

    #[test]
    fn fum_full_mask_equals_dense() {
        let cfg = SimConfig::edge();
        let mask = Tensor::from_fn(&[8, 8], |_| 1.0);
        let (_, _, t) = fum_fetch(&cfg, &mask, 32);
        let dense = frac_fetch_dense(&cfg, 16, 32); // l = 8*2
        assert_eq!(t.dram_bytes, dense.dram_bytes);
    }

    #[test]
    fn fum_single_block_touches_one_row_and_col() {
        let cfg = SimConfig::edge();
        let mut mask = Tensor::zeros(&[4, 4]);
        mask.set(2, 1, 1.0);
        let (rt, ct, t) = fum_fetch(&cfg, &mask, 16);
        assert_eq!((rt, ct), (1, 1));
        // 2 block-rows worth: (1+1) * block(2) * dh(16) * 1.5B(12 frac bits)
        assert_eq!(t.dram_bytes, 2.0 * 2.0 * 16.0 * 1.5);
    }

    #[test]
    fn prop_fum_never_exceeds_dense() {
        check("FUM bytes <= dense bytes, equal iff all rows+cols touched", 100, |g| {
            let cfg = SimConfig::edge();
            let nb = g.usize(1, 16);
            let dh = g.usize(4, 64);
            let mut r = SplitMix64::new(g.u64(0, u64::MAX / 2));
            let p = g.f64(0.0, 1.0);
            let mask = Tensor::from_fn(&[nb, nb], |_| {
                f32::from(r.next_f64() < p)
            });
            let (_, _, fum) = fum_fetch(&cfg, &mask, dh);
            let dense = frac_fetch_dense(&cfg, nb * cfg.block, dh);
            prop_assert(
                fum.dram_bytes <= dense.dram_bytes + 1e-9,
                "fum <= dense",
            )?;
            let all_kept = mask.data().iter().all(|&m| m > 0.0);
            if all_kept {
                prop_assert(
                    (fum.dram_bytes - dense.dram_bytes).abs() < 1e-9,
                    "equal when everything kept",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn dram_cycles_respect_bandwidth() {
        let cfg = SimConfig::edge(); // 8 B/cycle
        let t = Traffic { dram_bytes: 800.0, sram_bytes: 0.0 };
        assert_eq!(t.dram_cycles(&cfg), 100.0);
    }

    #[test]
    fn energy_dominated_by_dram() {
        let cfg = SimConfig::edge();
        let t = Traffic { dram_bytes: 100.0, sram_bytes: 100.0 };
        let e = t.energy_pj(&cfg);
        assert!(e > 100.0 * cfg.e_dram_pj_per_byte * 0.99);
        assert!(cfg.e_dram_pj_per_byte / cfg.e_sram_pj_per_byte > 50.0);
    }
}
