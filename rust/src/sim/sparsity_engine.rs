//! Sparsity Engine (paper §IV-D, Fig. 6): a streaming unit that
//! receives block importances θ from the PE accumulators, tracks
//! min/max/sum per block-row, and on `END_R` emits the row threshold Θ
//! and mask; on `END_H` it compares the accumulated θ_Head against τ_H
//! and decides whether the rest of the head is skipped.
//!
//! The numerics are the streaming re-implementation of
//! `attention::hdp::{row_threshold, block_mask}` — the unit tests prove
//! the two agree, which is the SE's functional contract.

use super::config::SimConfig;

/// Cycle/energy cost of one head's SE pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct SeCost {
    pub cycles: f64,
    pub energy_pj: f64,
}

/// Streaming sparsity engine for one head.
#[derive(Debug)]
pub struct SparsityEngine {
    rho: f32,
    tau: f32,
    // per-row state (Fig. 6's internal memory + min/max/sum trackers)
    row_thetas: Vec<f32>,
    min: f32,
    max: f32,
    sum: f64,
    theta_head: f64,
    masks: Vec<Vec<bool>>,
    blocks_seen: usize,
}

impl SparsityEngine {
    pub fn new(rho: f32, tau: f32) -> Self {
        Self {
            // Same domain clamp as `attention::hdp::row_threshold`: the
            // threshold must never exceed the row max (or undercut the
            // row min), so out-of-domain rho behaves like the boundary
            // instead of pruning entire rows the functional path keeps.
            rho: rho.clamp(-1.0, 1.0),
            tau,
            row_thetas: Vec::new(),
            min: f32::INFINITY,
            max: f32::NEG_INFINITY,
            sum: 0.0,
            theta_head: 0.0,
            masks: Vec::new(),
            blocks_seen: 0,
        }
    }

    /// Ingest one block importance (PE accumulator tap).
    pub fn push_theta(&mut self, theta: f32) {
        self.row_thetas.push(theta);
        self.min = self.min.min(theta);
        self.max = self.max.max(theta);
        self.sum += theta as f64;
        self.theta_head += theta as f64;
        self.blocks_seen += 1;
    }

    /// END_R: a full row of blocks is complete — compute Θ, emit the
    /// row mask, reset row trackers.
    pub fn end_row(&mut self) {
        let n = self.row_thetas.len() as f32;
        assert!(n > 0.0, "END_R with no blocks");
        let mean = (self.sum / self.row_thetas.len() as f64) as f32;
        let threshold = if self.rho >= 0.0 {
            self.rho * self.max + (1.0 - self.rho) * mean
        } else {
            -self.rho * self.min + (1.0 + self.rho) * mean
        };
        let mask = self.row_thetas.iter().map(|&t| t >= threshold).collect();
        self.masks.push(mask);
        self.row_thetas.clear();
        self.min = f32::INFINITY;
        self.max = f32::NEG_INFINITY;
        self.sum = 0.0;
    }

    /// END_H: the Integer_Q × Integer_K pass is complete — the head
    /// survives iff θ_Head exceeds τ_H.
    pub fn end_head(&self) -> bool {
        assert!(self.row_thetas.is_empty(), "END_H before END_R");
        self.theta_head as f32 > self.tau
    }

    pub fn theta_head(&self) -> f32 {
        self.theta_head as f32
    }

    /// Row masks emitted so far.
    pub fn masks(&self) -> &[Vec<bool>] {
        &self.masks
    }

    pub fn kept_blocks(&self) -> usize {
        self.masks.iter().flatten().filter(|k| **k).count()
    }

    /// Cycle/energy cost: one cycle per θ ingested (comparators +
    /// trackers run at stream rate) plus one pass per row for mask
    /// emission.
    pub fn cost(&self, cfg: &SimConfig) -> SeCost {
        let per_block = self.blocks_seen as f64 * cfg.se_cycles_per_block;
        let per_row: f64 = self
            .masks
            .iter()
            .map(|m| m.len() as f64 * cfg.se_cycles_per_block)
            .sum();
        SeCost {
            cycles: per_block + per_row,
            energy_pj: (self.blocks_seen as f64 + per_row)
                * cfg.e_se_pj_per_block,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::hdp::{block_mask, row_threshold};
    use crate::tensor::Tensor;
    use crate::util::prop::{check, prop_assert};

    /// Run the streaming engine over a theta matrix.
    fn run_engine(theta: &Tensor, rho: f32, tau: f32) -> SparsityEngine {
        let mut se = SparsityEngine::new(rho, tau);
        for i in 0..theta.rows() {
            for j in 0..theta.cols() {
                se.push_theta(theta.at(i, j));
            }
            se.end_row();
        }
        se
    }

    #[test]
    fn matches_functional_mask() {
        let theta = Tensor::new(
            &[2, 4],
            vec![1.0, 5.0, 2.0, 8.0, 0.0, 0.0, 3.0, 9.0],
        );
        for rho in [-0.9f32, -0.3, 0.0, 0.4, 0.9] {
            let se = run_engine(&theta, rho, 0.0);
            let want = block_mask(&theta, rho);
            for i in 0..2 {
                for j in 0..4 {
                    assert_eq!(
                        se.masks()[i][j],
                        want.at(i, j) == 1.0,
                        "rho={rho} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_streaming_equals_batch() {
        check("SE streaming mask == functional block_mask", 100, |g| {
            let nbr = g.usize(1, 16);
            let nbc = g.usize(1, 16);
            let rho = g.f32(-0.95, 0.95);
            let theta = Tensor::new(
                &[nbr, nbc],
                (0..nbr * nbc).map(|_| g.f32(0.0, 50.0)).collect(),
            );
            let se = run_engine(&theta, rho, 0.0);
            let want = block_mask(&theta, rho);
            for i in 0..nbr {
                for j in 0..nbc {
                    prop_assert(
                        se.masks()[i][j] == (want.at(i, j) == 1.0),
                        format!("mismatch at ({i},{j}) rho={rho}"),
                    )?;
                }
            }
            // thresholds agree too
            let th = row_threshold(theta.row(0), rho);
            prop_assert(th.is_finite(), "finite threshold")
        });
    }

    #[test]
    fn out_of_domain_rho_clamps_like_functional_path() {
        // Regression: the PR 1 clamp in row_threshold must hold here
        // too — rho > 1 used to push the streaming threshold above the
        // row max and prune rows the functional path keeps.
        let theta = Tensor::new(&[2, 3], vec![1.0, 5.0, 5.0, 2.0, 0.5, 1.0]);
        for (rho, boundary) in [(1.5f32, 1.0f32), (100.0, 1.0),
                                (-2.0, -1.0), (-100.0, -1.0)] {
            let se = run_engine(&theta, rho, 0.0);
            let want = block_mask(&theta, boundary);
            for i in 0..2 {
                for j in 0..3 {
                    assert_eq!(se.masks()[i][j], want.at(i, j) == 1.0,
                               "rho={rho} ({i},{j})");
                }
            }
            // every block-row still keeps at least its argmax block
            assert!(se.masks().iter().all(|row| row.iter().any(|&k| k)),
                    "rho={rho} pruned an entire row");
        }
    }

    #[test]
    fn head_decision() {
        let theta = Tensor::new(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let se = run_engine(&theta, 0.0, 5.0);
        assert_eq!(se.theta_head(), 10.0);
        assert!(se.end_head()); // 10 > 5
        let se2 = run_engine(&theta, 0.0, 10.0);
        assert!(!se2.end_head()); // 10 !> 10
    }

    #[test]
    fn cost_scales_with_blocks() {
        let cfg = SimConfig::edge();
        let small = run_engine(&Tensor::zeros(&[2, 2]), 0.0, 0.0).cost(&cfg);
        let big = run_engine(&Tensor::zeros(&[8, 8]), 0.0, 0.0).cost(&cfg);
        assert!(big.cycles > small.cycles);
        assert!(big.energy_pj > small.energy_pj);
    }

    #[test]
    #[should_panic(expected = "END_H before END_R")]
    fn end_head_requires_completed_rows() {
        let mut se = SparsityEngine::new(0.0, 0.0);
        se.push_theta(1.0);
        se.end_head();
    }
}
