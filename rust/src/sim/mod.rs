//! Cycle-level model of the HDP co-processor (paper §IV) and the
//! baseline accelerators it is compared against.
//!
//! Structure mirrors Fig. 4: [`pe_array`] (output-stationary tiled
//! matmul), [`sparsity_engine`] (θ tracking → Θ/mask → head decision),
//! [`softmax_unit`] (polynomial exp + linear reciprocal),
//! [`memory`] (DRAM/SRAM + FUM), composed per head by [`core`] and
//! across cores/layers by [`accelerator`]. [`baselines`] re-implements
//! A3/SpAtten/Energon/AccelTran pruning policies on the same
//! substrates; [`config`] holds the geometry/energy tables including
//! the HDP-Edge and HDP-Server presets.

pub mod accelerator;
pub mod baselines;
pub mod config;
pub mod core;
pub mod memory;
pub mod pe_array;
pub mod softmax_unit;
pub mod sparsity_engine;

pub use accelerator::{estimate_batch, estimate_decode_batch,
                      estimate_decode_step, estimate_layer,
                      estimate_layer_dense, estimate_model,
                      estimate_prefill_chunk, run_layer,
                      ChipReport, DecodeProfile, RequestProfile};
pub use config::{MacKind, SimConfig, Widths, W12, W16};
pub use core::{cost_decode_head, cost_decode_head_causal, cost_head,
               cost_head_dense, cost_spill_transfer, run_head, HeadRun,
               Report};
pub use sparsity_engine::SparsityEngine;
