//! Baseline accelerator cost models on the *same* PE/DRAM/softmax
//! substrates — each implements its paper's pruning policy, so the
//! comparison isolates policy, not process node (DESIGN.md
//! §Substitutions). All models take the measured attention sparsity of
//! the workload as input and return a [`ChipReport`].
//!
//! | model      | prunes                      | decision cost           | DRAM behaviour            |
//! |------------|-----------------------------|-------------------------|---------------------------|
//! | dense      | nothing                     | —                       | fetch everything          |
//! | A3 [19]    | near-zero scores (elements) | sort-based candidates   | **fetch everything** (on-chip approximation only) |
//! | SpAtten[20]| tokens + heads, cascaded    | Top-K unit (sorter)     | fetch kept tokens         |
//! | Energon[15]| elements, multi-round       | low-precision pre-pass  | element-granular (uncoalesced) fetch |
//! | AccelTran  | elements below threshold    | free (comparator)       | fetch everything (dense layout) |
//! | HDP (ours) | 2×2 blocks + early heads    | integer pre-pass + SE   | FUM block-coalesced fetch |

use super::accelerator::ChipReport;
use super::config::{MacKind, SimConfig};
use super::memory::{fetch_full, k_operand_traffic};
use super::pe_array::{masked_matmul_cost, matmul_cost};
use super::softmax_unit::softmax_cost;

/// Dense K-operand fetch shared by the element-granular baselines.
fn k_fetch_dense(cfg: &SimConfig, l: usize, dh: usize)
    -> super::memory::Traffic {
    let nb = (l / cfg.block) as f64;
    k_operand_traffic(cfg, l, dh, cfg.bytes_per_elem(), nb * nb, nb * nb, nb)
}

/// Workload description shared by every baseline.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub n_layers: usize,
    pub seq_len: usize,
    pub d_head: usize,
    pub n_heads: usize,
    /// Fraction of attention entries that matter (measured on the
    /// trained model; the same number HDP's blocks approximate).
    pub kept_density: f32,
    /// Fraction of heads that are genuinely useful.
    pub head_kept_frac: f32,
}

fn roll_up(cfg: &SimConfig, per_head: super::core::Report, w: &Workload,
           heads_pruned_frac: f32) -> ChipReport {
    let heads = w.n_layers * w.n_heads;
    let per_core = heads as f64 / cfg.n_cores as f64;
    let compute = per_head.cycles * per_core.ceil();
    let dram = per_head.dram_bytes * heads as f64;
    ChipReport {
        cycles: compute.max(dram / cfg.dram_bytes_per_cycle),
        energy_pj: per_head.energy_pj * heads as f64,
        dram_bytes: dram,
        macs: per_head.macs * heads as f64,
        heads_total: heads,
        heads_pruned: (heads_pruned_frac * heads as f32).round() as usize,
        mean_kept_density: w.kept_density as f64,
    }
}

/// Dense attention accelerator (no sparsity support).
pub fn dense(cfg: &SimConfig, w: &Workload) -> ChipReport {
    roll_up(cfg, super::core::cost_head_dense(cfg, w.seq_len, w.d_head), w, 0.0)
}

/// A3-like: approximates/skips near-zero score computation via a
/// sort-based candidate search, but *requires loading all data onto the
/// chip* — no DRAM saving (its documented limitation).
pub fn a3(cfg: &SimConfig, w: &Workload) -> ChipReport {
    let (l, dh) = (w.seq_len, w.d_head);
    let d = w.kept_density as f64;
    let mut r = super::core::Report::default();
    // full Q/K fetch — the no-DRAM-saving property
    let mut t = fetch_full(cfg, l, dh);
    t.add(k_fetch_dense(cfg, l, dh));
    // candidate search: per query row, a sorted-key scan costs ~dh log dh
    let search_cycles = (l as f64) * (dh as f64) * (dh as f64).log2() / cfg.macs_per_cycle();
    // score compute only for kept candidates, full width
    let qk = masked_matmul_cost(cfg, l, dh, l, d, MacKind::Full);
    r.cycles += (qk.cycles + search_cycles).max(t.dram_cycles(cfg));
    r.energy_pj += qk.energy_pj + search_cycles * 0.1 + t.energy_pj(cfg);
    r.dram_bytes += t.dram_bytes;
    r.macs += qk.macs;

    let sm = softmax_cost(cfg, l, d * (l * l) as f64);
    r.cycles += sm.cycles;
    r.energy_pj += sm.energy_pj;

    let mut t2 = fetch_full(cfg, l, dh);
    t2.add(fetch_full(cfg, l, dh));
    let av = masked_matmul_cost(cfg, l, l, dh, d, MacKind::Full);
    r.cycles += av.cycles.max(t2.dram_cycles(cfg));
    r.energy_pj += av.energy_pj + t2.energy_pj(cfg);
    r.dram_bytes += t2.dram_bytes;
    r.macs += av.macs;
    roll_up(cfg, r, w, 0.0)
}

/// SpAtten-like: cascaded token pruning (rows/cols of the score matrix
/// shrink as layers go) + cascaded head pruning decided *after* full
/// head computation, both via Top-K sorters.
pub fn spatten(cfg: &SimConfig, w: &Workload) -> ChipReport {
    let heads = w.n_layers * w.n_heads;
    // Tokens kept decay linearly toward the same net element density
    // HDP reaches; heads decay toward head_kept_frac by the last layer.
    let mut total = ChipReport::default();
    let target_tok = (w.kept_density as f64).sqrt(); // row×col factor
    for layer in 0..w.n_layers {
        let fl = (layer + 1) as f64 / w.n_layers as f64;
        let tok_frac = 1.0 - (1.0 - target_tok) * fl;
        let head_frac = 1.0 - (1.0 - w.head_kept_frac as f64) * fl;
        let l_eff = ((w.seq_len as f64) * tok_frac).ceil() as usize;
        let heads_alive = ((w.n_heads as f64) * head_frac).ceil() as usize;
        let mut per_head = super::core::cost_head_dense(cfg, l_eff, w.d_head);
        // Top-K token selection: bitonic-ish sorter, l log^2 l cycles.
        let ll = w.seq_len as f64;
        let topk_cycles = ll * ll.log2() * ll.log2() / cfg.macs_per_cycle();
        per_head.cycles += topk_cycles;
        per_head.energy_pj += topk_cycles * 0.2;
        let wl = Workload { n_layers: 1, n_heads: heads_alive, ..*w };
        total.add_serial(&roll_up(cfg, per_head, &wl, 0.0));
    }
    total.heads_total = heads;
    total.heads_pruned =
        heads - ((w.head_kept_frac * heads as f32).round() as usize).min(heads);
    total
}

/// Energon-like: a low-precision (int-field) filtering pre-pass over
/// all Q·K, then full-precision compute for selected elements. The
/// selected-element fetch is *uncoalesced* (element-granular sparsity):
/// every selected element pays a whole burst.
pub fn energon(cfg: &SimConfig, w: &Workload) -> ChipReport {
    let (l, dh) = (w.seq_len, w.d_head);
    let d = w.kept_density as f64;
    let nb = (l / cfg.block) as f64;
    let mut r = super::core::Report::default();
    // pre-pass: low-precision over everything (mixed precision is its
    // trick — same idea as HDP's integer pass)
    let int_bytes = cfg.widths.int_field as f64 / 8.0;
    let mut t = k_operand_traffic(cfg, l, dh, int_bytes, nb * nb, nb * nb, nb);
    t.dram_bytes += l as f64 * dh as f64 * int_bytes;
    t.sram_bytes += l as f64 * dh as f64 * int_bytes;
    let pre = matmul_cost(cfg, l, dh, l, MacKind::IntInt);
    r.cycles += pre.cycles.max(t.dram_cycles(cfg));
    r.energy_pj += pre.energy_pj + t.energy_pj(cfg);
    r.dram_bytes += t.dram_bytes;
    r.macs += pre.macs;

    // second round: full-precision for the selected *elements*. The
    // sparsity is element-granular (not block-coalesced), so streamed
    // fetches pay a ~1.5x burst-fragmentation premium — the irregular-
    // access weakness the paper points at.
    let sel = d * (l * l) as f64;
    let touched = nb * (1.0 - (1.0 - d).powf(nb));
    let mut t2 = k_operand_traffic(
        cfg, l, dh, cfg.bytes_per_elem(), d * nb * nb, nb * nb, touched);
    t2.dram_bytes *= 1.5;
    t2.sram_bytes *= 1.5;
    let qk = masked_matmul_cost(cfg, l, dh, l, d, MacKind::Full);
    r.cycles += qk.cycles.max(t2.dram_cycles(cfg));
    r.energy_pj += qk.energy_pj + t2.energy_pj(cfg);
    r.dram_bytes += t2.dram_bytes;
    r.macs += qk.macs;

    let sm = softmax_cost(cfg, l, sel);
    r.cycles += sm.cycles;
    r.energy_pj += sm.energy_pj;

    let mut t3 = fetch_full(cfg, l, dh);
    t3.add(fetch_full(cfg, l, dh));
    let av = masked_matmul_cost(cfg, l, l, dh, d, MacKind::Full);
    r.cycles += av.cycles.max(t3.dram_cycles(cfg));
    r.energy_pj += av.energy_pj + t3.energy_pj(cfg);
    r.dram_bytes += t3.dram_bytes;
    r.macs += av.macs;
    roll_up(cfg, r, w, 0.0)
}

/// AccelTran-like: threshold (comparator) element pruning inside the
/// matmuls; dense data layout, so DRAM traffic stays dense and skipped
/// elements still cost pipeline bubbles (half a slot).
pub fn acceltran(cfg: &SimConfig, w: &Workload) -> ChipReport {
    let (l, dh) = (w.seq_len, w.d_head);
    let d = w.kept_density as f64;
    let eff = d + (1.0 - d) * 0.5; // bubbles on skipped elements
    let mut r = super::core::Report::default();
    let mut t = fetch_full(cfg, l, dh);
    t.add(k_fetch_dense(cfg, l, dh)); // dense layout: fetch everything
    let qk = masked_matmul_cost(cfg, l, dh, l, eff, MacKind::Full);
    // energy only for the really-computed part:
    let qk_real = masked_matmul_cost(cfg, l, dh, l, d, MacKind::Full);
    r.cycles += qk.cycles.max(t.dram_cycles(cfg));
    r.energy_pj += qk_real.energy_pj + t.energy_pj(cfg);
    r.dram_bytes += t.dram_bytes;
    r.macs += qk_real.macs;

    let sm = softmax_cost(cfg, l, d * (l * l) as f64);
    r.cycles += sm.cycles;
    r.energy_pj += sm.energy_pj;

    let mut t2 = fetch_full(cfg, l, dh);
    t2.add(fetch_full(cfg, l, dh));
    let av = masked_matmul_cost(cfg, l, l, dh, eff, MacKind::Full);
    let av_real = masked_matmul_cost(cfg, l, l, dh, d, MacKind::Full);
    r.cycles += av.cycles.max(t2.dram_cycles(cfg));
    r.energy_pj += av_real.energy_pj + t2.energy_pj(cfg);
    r.dram_bytes += t2.dram_bytes;
    r.macs += av_real.macs;
    roll_up(cfg, r, w, 0.0)
}

/// HDP itself through the same closed-form interface.
pub fn hdp(cfg: &SimConfig, w: &Workload) -> ChipReport {
    super::accelerator::estimate_model(
        cfg, w.n_layers, w.seq_len, w.d_head, w.n_heads,
        w.kept_density, w.head_kept_frac, false,
    )
}

/// Table I of the paper: qualitative capability matrix, kept in code so
/// the repro harness prints what the implementations actually support.
pub fn table1() -> Vec<(&'static str, [bool; 6])> {
    // columns: head pruning, block pruning, approximation, tiled matmul,
    //          sparsity-aware, dynamic inference
    vec![
        ("A3", [false, false, true, false, false, true]),
        ("SpAtten", [true, false, false, false, true, true]),
        ("Energon", [false, false, false, false, true, true]),
        ("AccelTran", [false, false, false, true, true, true]),
        ("HDP (ours)", [true, true, true, true, true, true]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workload() -> Workload {
        Workload {
            n_layers: 4,
            seq_len: 128,
            d_head: 32,
            n_heads: 8,
            kept_density: 0.30,
            head_kept_frac: 0.85,
        }
    }

    #[test]
    fn hdp_wins_energy_against_all() {
        // The paper's headline: HDP saves energy vs every baseline at
        // its operating point (cheap integer decisions + FUM + early
        // head pruning).
        let cfg = SimConfig::edge();
        let w = workload();
        let ours = hdp(&cfg, &w).energy_pj;
        for (name, rep) in [
            ("dense", dense(&cfg, &w)),
            ("a3", a3(&cfg, &w)),
            ("energon", energon(&cfg, &w)),
            ("acceltran", acceltran(&cfg, &w)),
        ] {
            assert!(ours < rep.energy_pj, "{name}: ours {ours} vs {}", rep.energy_pj);
        }
    }

    #[test]
    fn a3_saves_no_dram() {
        let cfg = SimConfig::edge();
        let w = workload();
        let d = dense(&cfg, &w);
        let a = a3(&cfg, &w);
        assert!((a.dram_bytes - d.dram_bytes).abs() / d.dram_bytes < 0.01,
                "A3 must fetch everything");
    }

    #[test]
    fn hdp_saves_dram_at_long_sequences() {
        // FUM pays off once K no longer fits in the core buffer and must
        // be re-streamed (the paper's l >= 512 regime).
        let cfg = SimConfig::edge();
        let w = Workload { seq_len: 512, d_head: 64, ..workload() };
        let d = dense(&cfg, &w);
        let h = hdp(&cfg, &w);
        assert!(h.dram_bytes < 0.7 * d.dram_bytes,
                "hdp {} vs dense {}", h.dram_bytes, d.dram_bytes);
    }

    #[test]
    fn everyone_beats_dense_on_cycles() {
        let cfg = SimConfig::edge();
        let w = workload();
        let d = dense(&cfg, &w).cycles;
        for (name, rep) in [
            ("a3", a3(&cfg, &w)),
            ("spatten", spatten(&cfg, &w)),
            ("energon", energon(&cfg, &w)),
            ("acceltran", acceltran(&cfg, &w)),
            ("hdp", hdp(&cfg, &w)),
        ] {
            assert!(rep.cycles < d, "{name} {} vs dense {d}", rep.cycles);
        }
    }

    #[test]
    fn speedup_grows_with_seq_len() {
        // Attention dominance grows quadratically; HDP's advantage with it.
        let cfg = SimConfig::edge();
        let mut last = 0.0;
        for l in [64usize, 128, 256, 512] {
            let w = Workload { seq_len: l, ..workload() };
            let s = dense(&cfg, &w).cycles / hdp(&cfg, &w).cycles;
            assert!(s > last * 0.8, "speedup should not collapse: {s} at l={l}");
            last = s;
        }
        assert!(last > 1.8, "long-sequence speedup {last}");
    }

    #[test]
    fn energon_pays_uncoalesced_dram_premium_vs_hdp() {
        let cfg = SimConfig::edge();
        let w = workload();
        assert!(energon(&cfg, &w).dram_bytes > hdp(&cfg, &w).dram_bytes);
    }

    #[test]
    fn table1_matches_paper() {
        let t = table1();
        assert_eq!(t.len(), 5);
        let hdp_row = t.iter().find(|(n, _)| n.starts_with("HDP")).unwrap();
        assert!(hdp_row.1.iter().all(|&b| b), "HDP checks every column");
        let a3_row = t.iter().find(|(n, _)| *n == "A3").unwrap();
        assert!(a3_row.1[2] && !a3_row.1[0], "A3: approximation, no head pruning");
        let sp = t.iter().find(|(n, _)| *n == "SpAtten").unwrap();
        assert!(sp.1[0] && !sp.1[1], "SpAtten: head pruning, no block pruning");
    }
}
