//! Co-processor geometry, timing and energy model.
//!
//! The paper reports two ASIC instances, **HDP-Edge** and **HDP-Server**
//! (§VI), without publishing the full PPA tables in the provided text;
//! we therefore parameterize the simulator with an explicit,
//! documented cost table and report *relative* latency/energy (which is
//! what the comparisons claim). Energy constants follow the usual
//! Horowitz-style scaling used by SpAtten/Energon evaluations:
//!
//! * a b-bit × c-bit multiply costs ~ (b·c)/(16·16) of a 16-bit MAC —
//!   this is exactly why HDP's integer-only decision phase (4×4) and
//!   dropped FQ·FK term (12×12) save energy;
//! * off-chip DRAM access costs ~two orders of magnitude more per byte
//!   than SRAM — why FUM (fetch-upon-mask) and early head pruning
//!   dominate the savings at long sequence lengths.

/// Fixed-point field widths used in cost scaling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Widths {
    /// Total operand width in bits (sign + int + frac).
    pub total: u32,
    /// Integer field (incl. sign) — the decision phase's operand width.
    pub int_field: u32,
    /// Fraction field.
    pub frac_field: u32,
}

pub const W16: Widths = Widths { total: 16, int_field: 4, frac_field: 12 };
pub const W12: Widths = Widths { total: 12, int_field: 4, frac_field: 8 };

/// Operand kinds for a MAC, used to scale multiplier energy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MacKind {
    /// int × int (the Integer_atten pass).
    IntInt,
    /// int × frac (the two approximation fractions).
    IntFrac,
    /// frac × frac (only the exact/no-approximation arm computes this).
    FracFrac,
    /// full-width × full-width (dense baselines).
    Full,
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub name: &'static str,
    pub n_cores: usize,
    /// PE array geometry per core (pe_rows × pe_cols MACs per cycle).
    pub pe_rows: usize,
    pub pe_cols: usize,
    pub freq_ghz: f64,
    /// Off-chip bandwidth, bytes per cycle (per chip, shared by cores).
    pub dram_bytes_per_cycle: f64,
    /// Energy constants (picojoules).
    pub e_mac16_pj: f64,
    pub e_sram_pj_per_byte: f64,
    pub e_dram_pj_per_byte: f64,
    /// On-chip buffer per core (bytes) — decides whether the K operand
    /// is resident or re-streamed per Q block-row (the regime where FUM
    /// pays off, §IV-A).
    pub sram_bytes: f64,
    /// Softmax unit: parallel lanes, per-element exp cost and per-row
    /// reciprocal cost.
    pub softmax_lanes: f64,
    pub e_exp_pj: f64,
    pub exp_cycles_per_elem: f64,
    pub recip_cycles_per_row: f64,
    /// Sparsity engine per-theta processing cost.
    pub se_cycles_per_block: f64,
    pub e_se_pj_per_block: f64,
    /// Operand widths (16-bit main profile, 12-bit SpAtten comparison).
    pub widths: Widths,
    /// Pruning block edge.
    pub block: usize,
}

impl SimConfig {
    /// Single-core edge instance (paper's HDP-Edge).
    pub fn edge() -> SimConfig {
        SimConfig {
            name: "hdp-edge",
            n_cores: 1,
            pe_rows: 4,
            pe_cols: 8,
            freq_ghz: 1.0,
            dram_bytes_per_cycle: 8.0, // ~8 GB/s @ 1 GHz (LPDDR4-class)
            sram_bytes: 32.0 * 1024.0,
            softmax_lanes: 8.0,
            e_mac16_pj: 0.3,
            e_sram_pj_per_byte: 0.15,
            e_dram_pj_per_byte: 20.0,
            e_exp_pj: 0.6,
            exp_cycles_per_elem: 1.0,
            recip_cycles_per_row: 4.0,
            se_cycles_per_block: 1.0,
            e_se_pj_per_block: 0.05,
            widths: W16,
            block: 2,
        }
    }

    /// Multi-core server instance (paper's HDP-Server).
    pub fn server() -> SimConfig {
        SimConfig {
            name: "hdp-server",
            n_cores: 4,
            pe_rows: 8,
            pe_cols: 16,
            freq_ghz: 1.0,
            dram_bytes_per_cycle: 64.0, // ~64 GB/s @ 1 GHz (HBM-class slice)
            sram_bytes: 128.0 * 1024.0,
            ..Self::edge()
        }
    }

    pub fn with_widths(mut self, w: Widths) -> Self {
        self.widths = w;
        self
    }

    /// MACs retired per cycle by one core's PE array at full width.
    pub fn macs_per_cycle(&self) -> f64 {
        (self.pe_rows * self.pe_cols) as f64
    }

    /// Precision-scalable MAC throughput (DVAFS-style): a multiplier
    /// sized for `total`-bit operands retires `16/max(width)` narrow
    /// MACs per cycle — this is what makes HDP's 4-bit integer decision
    /// pass cheap in *time* as well as energy.
    pub fn macs_per_cycle_for(&self, kind: MacKind) -> f64 {
        let w = self.widths;
        let widest = match kind {
            MacKind::IntInt => w.int_field,
            MacKind::IntFrac | MacKind::FracFrac => w.frac_field,
            MacKind::Full => w.total,
        };
        self.macs_per_cycle() * (w.total as f64 / widest as f64)
    }

    /// Bytes per stored element in DRAM/SRAM.
    pub fn bytes_per_elem(&self) -> f64 {
        self.widths.total as f64 / 8.0
    }

    /// Energy of one MAC of the given kind (bit-width scaled).
    pub fn mac_energy_pj(&self, kind: MacKind) -> f64 {
        let w = self.widths;
        let bits = |k: MacKind| -> f64 {
            match k {
                MacKind::IntInt => (w.int_field * w.int_field) as f64,
                MacKind::IntFrac => (w.int_field * w.frac_field) as f64,
                MacKind::FracFrac => (w.frac_field * w.frac_field) as f64,
                MacKind::Full => (w.total * w.total) as f64,
            }
        };
        self.e_mac16_pj * bits(kind) / (16.0 * 16.0)
    }

    pub fn cycles_to_seconds(&self, cycles: f64) -> f64 {
        cycles / (self.freq_ghz * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_sane() {
        let e = SimConfig::edge();
        let s = SimConfig::server();
        assert_eq!(e.n_cores, 1);
        assert!(s.n_cores > e.n_cores);
        assert!(s.macs_per_cycle() > e.macs_per_cycle());
        assert!(s.dram_bytes_per_cycle > e.dram_bytes_per_cycle);
        assert_eq!(e.bytes_per_elem(), 2.0);
    }

    #[test]
    fn mac_energy_ordering() {
        // int*int < int*frac < frac*frac < full — the approximation's
        // energy argument in one assert.
        let c = SimConfig::edge();
        let ii = c.mac_energy_pj(MacKind::IntInt);
        let if_ = c.mac_energy_pj(MacKind::IntFrac);
        let ff = c.mac_energy_pj(MacKind::FracFrac);
        let full = c.mac_energy_pj(MacKind::Full);
        assert!(ii < if_ && if_ < ff && ff < full);
        assert!((full - c.e_mac16_pj).abs() < 1e-12);
        // dropped FQ·FK saves 144/256 = 56% of a full MAC's multiplier energy
        assert!((ff / full - 144.0 / 256.0).abs() < 1e-12);
    }

    #[test]
    fn twelve_bit_profile() {
        let c = SimConfig::edge().with_widths(W12);
        assert_eq!(c.bytes_per_elem(), 1.5);
        assert!(c.mac_energy_pj(MacKind::Full) < 0.3);
    }

    #[test]
    fn time_conversion() {
        let c = SimConfig::edge();
        assert!((c.cycles_to_seconds(1e9) - 1.0).abs() < 1e-12);
    }
}
