//! One HDP core's per-head pipeline (paper §IV-A workflow):
//!
//! 1. fetch integer fields of Q, K → `Integer_Q × Integer_K` on the PE
//!    array, block importances tapped off the accumulators into the SE;
//! 2. SE emits per-row masks (END_R) and the head decision (END_H);
//! 3. head pruned → stop: the remaining ~¾ of compute and *all*
//!    remaining DRAM traffic are skipped;
//! 4. head kept → FUM-fetch fraction fields for surviving blocks only,
//!    compute the two fraction products on the PE array, sum with the
//!    adder, softmax the kept entries, multiply by V, write back.
//!
//! Each phase's latency is `max(compute, DRAM)` — the tiled dataflow
//! double-buffers fetches behind compute (§IV-B).

use crate::attention::hdp::{hdp_head, HdpHeadOutput, HdpParams};
use crate::tensor::Tensor;

use super::config::{MacKind, SimConfig};
use super::memory::{fetch_full, k_operand_traffic, Traffic};
use super::pe_array::{masked_matmul_cost, matmul_cost};
use super::softmax_unit::softmax_cost;

/// Mask statistics the memory model needs: kept blocks and the unions
/// of touched block-rows / block-columns.
#[derive(Debug, Clone, Copy)]
struct MaskStats {
    kept_blocks: f64,
    total_blocks: f64,
    union_rows: f64,
    union_cols: f64,
}

impl MaskStats {
    fn from_mask(mask: &Tensor) -> MaskStats {
        let (nbr, nbc) = (mask.rows(), mask.cols());
        let mut rows = vec![false; nbr];
        let mut cols = vec![false; nbc];
        let mut kept = 0.0;
        for i in 0..nbr {
            for j in 0..nbc {
                if mask.at(i, j) > 0.0 {
                    kept += 1.0;
                    rows[i] = true;
                    cols[j] = true;
                }
            }
        }
        MaskStats {
            kept_blocks: kept,
            total_blocks: (nbr * nbc) as f64,
            union_rows: rows.iter().filter(|t| **t).count() as f64,
            union_cols: cols.iter().filter(|t| **t).count() as f64,
        }
    }

    /// Expected-value stats for a Bernoulli(d) mask over nb×nb blocks.
    fn from_density(nb: f64, d: f64) -> MaskStats {
        let touched = nb * (1.0 - (1.0 - d).powf(nb));
        MaskStats {
            kept_blocks: d * nb * nb,
            total_blocks: nb * nb,
            union_rows: touched,
            union_cols: touched,
        }
    }
}

/// Cost record of one head pass (or an aggregate of many).
#[derive(Debug, Clone, Copy, Default)]
pub struct Report {
    pub cycles: f64,
    pub energy_pj: f64,
    pub dram_bytes: f64,
    pub sram_bytes: f64,
    pub macs: f64,
}

impl Report {
    pub fn add(&mut self, o: &Report) {
        self.cycles += o.cycles;
        self.energy_pj += o.energy_pj;
        self.dram_bytes += o.dram_bytes;
        self.sram_bytes += o.sram_bytes;
        self.macs += o.macs;
    }

    pub fn seconds(&self, cfg: &SimConfig) -> f64 {
        cfg.cycles_to_seconds(self.cycles)
    }
}

/// A head pass with its functional result attached.
#[derive(Debug)]
pub struct HeadRun {
    pub out: HdpHeadOutput,
    pub report: Report,
}

fn phase(report: &mut Report, cfg: &SimConfig, compute_cycles: f64,
         compute_energy: f64, traffic: Traffic) {
    report.cycles += compute_cycles.max(traffic.dram_cycles(cfg));
    report.energy_pj += compute_energy + traffic.energy_pj(cfg);
    report.dram_bytes += traffic.dram_bytes;
    report.sram_bytes += traffic.sram_bytes;
}

/// Run one head functionally *and* account its cycles/energy/traffic.
pub fn run_head(
    cfg: &SimConfig,
    iq: &Tensor,
    fq: &Tensor,
    ik: &Tensor,
    fk: &Tensor,
    v: &Tensor,
    params: HdpParams,
) -> HeadRun {
    let (l, dh) = (iq.rows(), iq.cols());
    let out = hdp_head(iq, fq, ik, fk, v, params);
    let report = cost_head(cfg, l, dh, Some(&out.mask), out.kept_density,
                           out.head_kept, params.use_ff);
    HeadRun { out, report }
}

/// Pure cost model of one head given its pruning outcome. When `mask`
/// is present the FUM traffic is exact; otherwise it is estimated from
/// the density (used by the closed-form sweeps).
pub fn cost_head(
    cfg: &SimConfig,
    l: usize,
    dh: usize,
    mask: Option<&Tensor>,
    kept_density: f32,
    head_kept: bool,
    use_ff: bool,
) -> Report {
    let mut r = Report::default();
    let d = kept_density as f64;
    let nb = (l / cfg.block) as f64;
    let int_bytes = cfg.widths.int_field as f64 / 8.0;
    let frac_bytes = cfg.widths.frac_field as f64 / 8.0;
    let stats = match mask {
        Some(m) => MaskStats::from_mask(m),
        None => MaskStats::from_density(nb, d),
    };
    let dense_stats = MaskStats {
        kept_blocks: nb * nb,
        total_blocks: nb * nb,
        union_rows: nb,
        union_cols: nb,
    };

    // Phase 1: integer-field fetch (Q once, K resident-or-streamed) +
    // Integer_Q × Integer_K with the SE consuming θ at stream rate.
    let mut int_fetch = Traffic {
        dram_bytes: l as f64 * dh as f64 * int_bytes, // IQ once
        sram_bytes: l as f64 * dh as f64 * int_bytes,
    };
    int_fetch.add(k_operand_traffic(
        cfg, l, dh, int_bytes,
        dense_stats.kept_blocks, dense_stats.total_blocks, nb,
    ));
    let int_mm = matmul_cost(cfg, l, dh, l, MacKind::IntInt);
    let se_cycles = nb * nb * cfg.se_cycles_per_block; // concurrent stream
    let se_energy = nb * nb * 2.0 * cfg.e_se_pj_per_block;
    phase(&mut r, cfg, int_mm.cycles.max(se_cycles),
          int_mm.energy_pj + se_energy, int_fetch);
    r.macs += int_mm.macs;

    if !head_kept {
        return r; // early head pruning: everything below is skipped
    }

    // Phase 2: FUM fraction fetch (FQ rows touched once; FK resident-
    // or-streamed gated by the mask) + the two fraction products
    // (+ exact FF term if approximation is disabled).
    let mut fum = Traffic {
        dram_bytes: stats.union_rows * cfg.block as f64 * dh as f64 * frac_bytes,
        sram_bytes: stats.union_rows * cfg.block as f64 * dh as f64 * frac_bytes,
    };
    fum.add(k_operand_traffic(
        cfg, l, dh, frac_bytes,
        stats.kept_blocks, stats.total_blocks, stats.union_cols,
    ));
    let mut frac_mm = masked_matmul_cost(cfg, l, dh, l, d, MacKind::IntFrac);
    frac_mm.add(masked_matmul_cost(cfg, l, dh, l, d, MacKind::IntFrac));
    if use_ff {
        frac_mm.add(masked_matmul_cost(cfg, l, dh, l, d, MacKind::FracFrac));
    }
    // Adder stage: 2 adds per kept score element, wide accumulators.
    let kept_elems = d * (l * l) as f64;
    let adder_cycles = kept_elems / cfg.macs_per_cycle();
    let adder_energy = kept_elems * 2.0 * 0.01; // pJ-level adds
    phase(&mut r, cfg, frac_mm.cycles + adder_cycles,
          frac_mm.energy_pj + adder_energy, fum);
    r.macs += frac_mm.macs;

    // Phase 3: softmax over kept entries.
    let sm = softmax_cost(cfg, l, kept_elems);
    phase(&mut r, cfg, sm.cycles, sm.energy_pj, Traffic::default());

    // Phase 4: fetch V (full precision) + attention_prob x V skipping
    // pruned columns, then write the head output back to DRAM.
    let v_fetch = fetch_full(cfg, l, dh);
    let av = masked_matmul_cost(cfg, l, l, dh, d, MacKind::Full);
    let writeback = fetch_full(cfg, l, dh);
    let mut t = v_fetch;
    t.add(writeback);
    phase(&mut r, cfg, av.cycles, av.energy_pj, t);
    r.macs += av.macs;

    r
}

/// Pure cost model of one *incremental decode step* for one head over
/// a cached context of `l` tokens: the integer row+column pass against
/// the cached integer fields (the quadratic→linear collapse a KV cache
/// buys), the sparsity-engine θ update, and — for kept heads —
/// FUM-gated fraction products, softmax and `P·V` for the **single
/// query row's** kept columns. Cached pages stream from DRAM (that is
/// what a KV cache is: state too large to pin on chip); pruned heads
/// stop after the decision exactly as in [`cost_head`].
pub fn cost_decode_head(
    cfg: &SimConfig,
    l: usize,
    dh: usize,
    kept_density: f32,
    head_kept: bool,
    use_ff: bool,
) -> Report {
    let mut r = Report::default();
    let d = kept_density as f64;
    let lf = l as f64;
    let dhf = dh as f64;
    let nb = (lf / cfg.block as f64).ceil();
    let int_bytes = cfg.widths.int_field as f64 / 8.0;
    let frac_bytes = cfg.widths.frac_field as f64 / 8.0;

    // Phase 1: new-row × cached-K and cached-Q × new-column integer
    // scores (2·l·d_h MACs — linear in context, unlike the full l²·d_h
    // pass), with the SE folding θ for the touched block-row and
    // block-column at stream rate.
    let int_traffic = Traffic {
        dram_bytes: 2.0 * (lf + 1.0) * dhf * int_bytes,
        sram_bytes: 2.0 * (lf + 1.0) * dhf * int_bytes,
    };
    let row_mm = matmul_cost(cfg, 1, dh, l, MacKind::IntInt);
    let col_mm = matmul_cost(cfg, l, dh, 1, MacKind::IntInt);
    let se_cycles = 2.0 * nb * cfg.se_cycles_per_block;
    let se_energy = 2.0 * nb * 2.0 * cfg.e_se_pj_per_block;
    phase(&mut r, cfg, (row_mm.cycles + col_mm.cycles).max(se_cycles),
          row_mm.energy_pj + col_mm.energy_pj + se_energy, int_traffic);
    r.macs += row_mm.macs + col_mm.macs;

    if !head_kept {
        return r; // early head pruning: everything below is skipped
    }

    // Phase 2: FUM — fraction fields fetched for the kept columns of
    // the one query row only, plus the query row's own fraction field.
    let kept_cols = d * lf;
    let fum = Traffic {
        dram_bytes: (kept_cols + 1.0) * dhf * frac_bytes,
        sram_bytes: (kept_cols + 1.0) * dhf * frac_bytes,
    };
    let mut frac_mm = masked_matmul_cost(cfg, 1, dh, l, d, MacKind::IntFrac);
    frac_mm.add(masked_matmul_cost(cfg, 1, dh, l, d, MacKind::IntFrac));
    if use_ff {
        frac_mm.add(masked_matmul_cost(cfg, 1, dh, l, d, MacKind::FracFrac));
    }
    let adder_cycles = kept_cols / cfg.macs_per_cycle();
    let adder_energy = kept_cols * 2.0 * 0.01;
    phase(&mut r, cfg, frac_mm.cycles + adder_cycles,
          frac_mm.energy_pj + adder_energy, fum);
    r.macs += frac_mm.macs;

    // Phase 3: softmax over the kept entries of one row.
    let sm = softmax_cost(cfg, 1, kept_cols);
    phase(&mut r, cfg, sm.cycles, sm.energy_pj, Traffic::default());

    // Phase 4: fetch kept V rows, accumulate the one output row, write
    // it back.
    let v_traffic = Traffic {
        dram_bytes: (kept_cols + 1.0) * dhf * cfg.bytes_per_elem(),
        sram_bytes: (kept_cols + 1.0) * dhf * cfg.bytes_per_elem(),
    };
    let av = masked_matmul_cost(cfg, 1, l, dh, d, MacKind::Full);
    phase(&mut r, cfg, av.cycles, av.energy_pj, v_traffic);
    r.macs += av.macs;
    r
}

/// Causal/windowed variant of [`cost_decode_head`]: the new query row
/// attends only to the last `window` cached tokens (or all of them
/// when unbounded), so the visible context — and with it the integer
/// pass, the θ fold, FUM traffic and `P·V` — clamps to
/// `min(l, window)`. The quadratic→linear collapse of the cached step
/// becomes *constant* in total context once the window saturates.
pub fn cost_decode_head_causal(
    cfg: &SimConfig,
    l: usize,
    window: Option<usize>,
    dh: usize,
    kept_density: f32,
    head_kept: bool,
    use_ff: bool,
) -> Report {
    let visible = window.map_or(l, |w| l.min(w));
    cost_decode_head(cfg, visible, dh, kept_density, head_kept, use_ff)
}

/// Cost of moving one session's KV pages (plus θ rows in causal mode)
/// through the spill tier: a pure DRAM stream in either direction —
/// no PE/SE compute overlaps it, so the latency is the transfer itself
/// at DRAM bandwidth. `bytes` is the `SpillStats` byte count for the
/// spill or restore being modelled.
pub fn cost_spill_transfer(cfg: &SimConfig, bytes: f64) -> Report {
    let mut r = Report::default();
    phase(&mut r, cfg, 0.0, 0.0,
          Traffic { dram_bytes: bytes, sram_bytes: bytes });
    r
}

/// Dense-attention cost of the same head on the same substrate
/// (no SE, no masks, full-width everything) — the speedup denominator.
pub fn cost_head_dense(cfg: &SimConfig, l: usize, dh: usize) -> Report {
    let mut r = Report::default();
    let nb = (l / cfg.block) as f64;
    let qk_fetch = {
        let mut t = fetch_full(cfg, l, dh); // Q once
        // K at full width, resident-or-streamed, nothing masked.
        t.add(k_operand_traffic(cfg, l, dh, cfg.bytes_per_elem(),
                                nb * nb, nb * nb, nb));
        t
    };
    let qk = matmul_cost(cfg, l, dh, l, MacKind::Full);
    phase(&mut r, cfg, qk.cycles, qk.energy_pj, qk_fetch);
    r.macs += qk.macs;

    let sm = softmax_cost(cfg, l, (l * l) as f64);
    phase(&mut r, cfg, sm.cycles, sm.energy_pj, Traffic::default());

    let mut t = fetch_full(cfg, l, dh); // V
    t.add(fetch_full(cfg, l, dh)); // writeback
    let av = matmul_cost(cfg, l, l, dh, MacKind::Full);
    phase(&mut r, cfg, av.cycles, av.energy_pj, t);
    r.macs += av.macs;
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{quant_split_tensor, QuantProfile};
    use crate::util::prop::{check, prop_assert};
    use crate::util::rng::SplitMix64;

    fn inputs(seed: u64, l: usize, dh: usize)
        -> (Tensor, Tensor, Tensor, Tensor, Tensor, f32) {
        let mut r = SplitMix64::new(seed);
        let mut randv = |n: usize| -> Vec<f32> {
            (0..n).map(|_| r.next_normal() as f32 * 2.0).collect()
        };
        let prof = QuantProfile::Q4_12;
        let (iq, fq, sq) = quant_split_tensor(&randv(l * dh), prof);
        let (ik, fk, sk) = quant_split_tensor(&randv(l * dh), prof);
        let inv = 1.0 / (sq * sk * (dh as f32).sqrt());
        (
            Tensor::new(&[l, dh], iq),
            Tensor::new(&[l, dh], fq),
            Tensor::new(&[l, dh], ik),
            Tensor::new(&[l, dh], fk),
            Tensor::new(&[l, dh], randv(l * dh)),
            inv,
        )
    }

    #[test]
    fn pruned_head_is_much_cheaper() {
        let cfg = SimConfig::edge();
        let (iq, fq, ik, fk, v, inv) = inputs(1, 64, 32);
        let kept = run_head(&cfg, &iq, &fq, &ik, &fk, &v,
            HdpParams { rho: 0.0, tau: -1.0, inv_scale: inv, ..Default::default() });
        let pruned = run_head(&cfg, &iq, &fq, &ik, &fk, &v,
            HdpParams { rho: 0.0, tau: 1e9, inv_scale: inv, ..Default::default() });
        assert!(kept.out.head_kept && !pruned.out.head_kept);
        assert!(pruned.report.cycles < 0.5 * kept.report.cycles);
        assert!(pruned.report.dram_bytes < 0.5 * kept.report.dram_bytes);
        assert!(pruned.report.energy_pj < 0.5 * kept.report.energy_pj);
    }

    #[test]
    fn hdp_beats_dense_on_cycles_and_energy() {
        // The headline claim at moderate sparsity.
        let cfg = SimConfig::edge();
        let (iq, fq, ik, fk, v, inv) = inputs(2, 128, 32);
        let run = run_head(&cfg, &iq, &fq, &ik, &fk, &v,
            HdpParams { rho: 0.5, tau: -1.0, inv_scale: inv, ..Default::default() });
        let dense = cost_head_dense(&cfg, 128, 32);
        assert!(run.out.kept_density < 0.6, "{}", run.out.kept_density);
        assert!(run.report.energy_pj < dense.energy_pj,
                "hdp {} vs dense {}", run.report.energy_pj, dense.energy_pj);
        assert!(run.report.cycles < dense.cycles);
    }

    #[test]
    fn estimate_close_to_exact_mask_accounting() {
        let cfg = SimConfig::edge();
        let (iq, fq, ik, fk, v, inv) = inputs(3, 64, 32);
        let run = run_head(&cfg, &iq, &fq, &ik, &fk, &v,
            HdpParams { rho: 0.3, tau: -1.0, inv_scale: inv, ..Default::default() });
        let est = cost_head(&cfg, 64, 32, None, run.out.kept_density,
                            true, false);
        let rel = (est.cycles - run.report.cycles).abs() / run.report.cycles;
        assert!(rel < 0.15, "estimate off by {rel}");
    }

    #[test]
    fn prop_cost_monotone_in_density() {
        check("head cost monotone in kept density", 50, |g| {
            let cfg = SimConfig::edge();
            let l = *g.choice(&[32usize, 64, 128]);
            let d1 = g.f32(0.0, 1.0);
            let d2 = g.f32(0.0, 1.0);
            let (lo, hi) = if d1 < d2 { (d1, d2) } else { (d2, d1) };
            let a = cost_head(&cfg, l, 32, None, lo, true, false);
            let b = cost_head(&cfg, l, 32, None, hi, true, false);
            prop_assert(a.cycles <= b.cycles + 1e-6, "cycles")?;
            prop_assert(a.energy_pj <= b.energy_pj + 1e-6, "energy")?;
            prop_assert(a.dram_bytes <= b.dram_bytes + 1e-6, "dram")
        });
    }

    #[test]
    fn prop_skipped_macs_match_mask() {
        // Work conservation: MACs performed = int pass + kept fraction
        // passes + kept AV.
        check("MAC accounting matches mask", 30, |g| {
            let cfg = SimConfig::edge();
            let l = *g.choice(&[16usize, 32]);
            let dh = 16;
            let (iq, fq, ik, fk, v, inv) = inputs(g.u64(0, 1 << 40), l, dh);
            let rho = g.f32(-0.5, 0.9);
            let run = run_head(&cfg, &iq, &fq, &ik, &fk, &v,
                HdpParams { rho, tau: -1.0, inv_scale: inv, ..Default::default() });
            let d = run.out.kept_density as f64;
            let lf = l as f64;
            let want = lf * lf * dh as f64 // int pass
                + 2.0 * d * lf * lf * dh as f64 // frac passes
                + d * lf * lf * dh as f64; // AV
            prop_assert(
                (run.report.macs - want).abs() / want < 1e-6,
                format!("macs {} want {}", run.report.macs, want),
            )
        });
    }

    #[test]
    fn decode_head_scales_linearly_not_quadratically() {
        let cfg = SimConfig::edge();
        let a = cost_decode_head(&cfg, 256, 32, 0.5, true, false);
        let b = cost_decode_head(&cfg, 1024, 32, 0.5, true, false);
        // 4x the context → ~4x the MACs (linear), nowhere near the
        // full-recompute 16x.
        assert!(b.macs / a.macs > 3.0 && b.macs / a.macs < 6.0,
                "{} vs {}", a.macs, b.macs);
        // pruned head stops after the integer/SE phase
        let pruned = cost_decode_head(&cfg, 1024, 32, 0.5, false, false);
        assert!(pruned.cycles < 0.7 * b.cycles);
        assert!(pruned.dram_bytes < b.dram_bytes);
        // exact arm costs more
        let ff = cost_decode_head(&cfg, 1024, 32, 0.5, true, true);
        assert!(ff.macs > b.macs && ff.energy_pj > b.energy_pj);
    }

    #[test]
    fn causal_decode_cost_saturates_at_the_window() {
        let cfg = SimConfig::edge();
        // Unbounded causal = the plain cached step.
        let unbounded = cost_decode_head_causal(&cfg, 1024, None, 32, 0.5,
                                                true, false);
        let plain = cost_decode_head(&cfg, 1024, 32, 0.5, true, false);
        assert_eq!(unbounded.cycles, plain.cycles);
        assert_eq!(unbounded.macs, plain.macs);
        // A 256-token window at 8k context costs exactly the 256-token
        // step — constant in total context once the window saturates.
        let w8k = cost_decode_head_causal(&cfg, 8192, Some(256), 32, 0.5,
                                          true, false);
        let w32k = cost_decode_head_causal(&cfg, 32768, Some(256), 32, 0.5,
                                           true, false);
        let short = cost_decode_head(&cfg, 256, 32, 0.5, true, false);
        assert_eq!(w8k.cycles, short.cycles);
        assert_eq!(w32k.cycles, w8k.cycles);
        assert!(w8k.macs < plain.macs);
        // A window wider than the context is a no-op clamp.
        let wide = cost_decode_head_causal(&cfg, 128, Some(4096), 32, 0.5,
                                           true, false);
        let exact = cost_decode_head(&cfg, 128, 32, 0.5, true, false);
        assert_eq!(wide.cycles, exact.cycles);
    }

    #[test]
    fn spill_transfer_is_linear_dram_traffic() {
        let cfg = SimConfig::edge();
        let one = cost_spill_transfer(&cfg, 1 << 20);
        let four = cost_spill_transfer(&cfg, 4 << 20);
        assert_eq!(one.dram_bytes, (1u64 << 20) as f64);
        assert!(one.cycles > 0.0 && one.energy_pj > 0.0);
        assert_eq!(one.macs, 0.0);
        assert!((four.cycles / one.cycles - 4.0).abs() < 1e-9);
        assert!((four.dram_bytes / one.dram_bytes - 4.0).abs() < 1e-9);
        let zero = cost_spill_transfer(&cfg, 0.0);
        assert_eq!(zero.cycles, 0.0);
        assert_eq!(zero.dram_bytes, 0.0);
    }

    #[test]
    fn use_ff_costs_more() {
        let cfg = SimConfig::edge();
        let approx = cost_head(&cfg, 64, 32, None, 0.5, true, false);
        let exact = cost_head(&cfg, 64, 32, None, 0.5, true, true);
        assert!(exact.energy_pj > approx.energy_pj);
        assert!(exact.macs > approx.macs);
    }

    #[test]
    fn dense_report_fields_populated() {
        let cfg = SimConfig::server();
        let d = cost_head_dense(&cfg, 128, 64);
        assert!(d.cycles > 0.0 && d.energy_pj > 0.0 && d.dram_bytes > 0.0);
        assert_eq!(d.macs, 2.0 * 128.0 * 128.0 * 64.0);
        assert!(d.seconds(&cfg) > 0.0);
    }
}
