//! Small row-major f32 tensor — the substrate the functional attention
//! models and the cycle simulator compute on. Deliberately minimal: the
//! heavy math lives in the AOT'd XLA executables; this type exists for
//! the rust-side mirrors (Algorithm 2 functional model, simulator
//! numerics, cross-validation against artifacts).

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(", self.shape)?;
        let n = self.data.len().min(8);
        for v in &self.data[..n] {
            write!(f, "{v:.3},")?;
        }
        if self.data.len() > n {
            write!(f, "…")?;
        }
        write!(f, ")")
    }
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} != data len {}", data.len());
        Self { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: (0..n).map(&mut f).collect() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    // -- 2-D access (the simulator works on matrices) -----------------------

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[r * self.shape[1] + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[r * self.shape[1] + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    /// `self [m,k] x other [k,n] -> [m,n]` (ikj loop order: streams the
    /// rhs row-major, vectorizes well).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (other.rows(), other.cols());
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let orow = &mut out[i * n..(i + 1) * n];
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// `self [m,k] x other^T where other is [n,k] -> [m,n]` — the
    /// Q·Kᵀ shape, dot-product form.
    pub fn matmul_nt(&self, other: &Tensor) -> Tensor {
        let m = self.rows();
        let n = other.rows();
        let mut out = vec![0.0f32; m * n];
        self.matmul_nt_into(other, &mut out);
        Tensor::new(&[m, n], out)
    }

    /// Allocation-free `matmul_nt` into a caller-owned buffer — the
    /// form the attention kernel's [`crate::attention::kernel::Workspace`]
    /// reuses across heads.
    ///
    /// Register-blocked microkernel: 4×4 output tiles accumulate in
    /// locals while both operands stream row-major, so each k step
    /// issues 16 independent FMAs (the naive per-element dot product
    /// serializes on one accumulator). Each `out[i][j]` is still a
    /// single running sum over `k` in ascending order, so results are
    /// bit-identical to the scalar loop — the integer-score path of
    /// Algorithm 2 depends on that.
    pub fn matmul_nt_into(&self, other: &Tensor, out: &mut [f32]) {
        let (m, k) = (self.rows(), self.cols());
        let (n, k2) = (other.rows(), other.cols());
        assert_eq!(k, k2, "matmul_nt inner dims {k} vs {k2}");
        assert_eq!(out.len(), m * n, "matmul_nt_into: out len {} != {m}x{n}", out.len());
        const MR: usize = 4;
        const NR: usize = 4;
        let a = &self.data;
        let b = &other.data;
        let mut i = 0;
        while i < m {
            let ih = MR.min(m - i);
            let mut j = 0;
            while j < n {
                let jh = NR.min(n - j);
                let mut acc = [[0.0f32; NR]; MR];
                for p in 0..k {
                    let mut bv = [0.0f32; NR];
                    for (jj, v) in bv.iter_mut().enumerate().take(jh) {
                        *v = b[(j + jj) * k + p];
                    }
                    for (ii, accrow) in acc.iter_mut().enumerate().take(ih) {
                        let av = a[(i + ii) * k + p];
                        for (jj, s) in accrow.iter_mut().enumerate().take(jh) {
                            *s += av * bv[jj];
                        }
                    }
                }
                for ii in 0..ih {
                    let orow = &mut out[(i + ii) * n + j..(i + ii) * n + j + jh];
                    orow.copy_from_slice(&acc[ii][..jh]);
                }
                j += NR;
            }
            i += MR;
        }
    }

    pub fn transpose2(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(&[n, m], out)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn abs_sum(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Row-wise softmax over a 2-D tensor. A row whose exponentials
    /// all vanish (every entry `-inf`, or everything 80+ below the row
    /// max — `sum == 0`) comes back as a zero row instead of the
    /// `0/0 = NaN` the naive normalization would produce.
    pub fn softmax_rows(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let row = self.row(i);
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            if mx == f32::NEG_INFINITY {
                continue; // fully-masked row: stays zero
            }
            let mut sum = 0.0f32;
            for (j, &x) in row.iter().enumerate() {
                // §Perf: entries 80+ below the row max underflow to 0
                // anyway (pruned-score sentinels in the HDP path);
                // skipping exp() made sparse softmax ~2x cheaper.
                let d = x - mx;
                let e = if d < -80.0 { 0.0 } else { d.exp() };
                out[i * n + j] = e;
                sum += e;
            }
            if sum == 0.0 {
                continue; // all exponentials underflowed: zero row
            }
            for j in 0..n {
                out[i * n + j] /= sum;
            }
        }
        Tensor::new(&[m, n], out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, prop_assert_close};
    use crate::util::rng::SplitMix64;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut r = SplitMix64::new(seed);
        Tensor::from_fn(shape, |_| r.next_normal() as f32)
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_nt_equals_matmul_of_transpose() {
        let a = randt(&[5, 7], 1);
        let b = randt(&[6, 7], 2);
        let d = a.matmul_nt(&b).max_abs_diff(&a.matmul(&b.transpose2()));
        assert!(d < 1e-5, "{d}");
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn matmul_shape_checked() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        a.matmul(&b);
    }

    #[test]
    fn transpose_involution() {
        let a = randt(&[3, 8], 3);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn softmax_rows_normalized() {
        let a = randt(&[4, 9], 4).scale(3.0);
        let s = a.softmax_rows();
        for i in 0..4 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(s.row(i).iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn softmax_fully_pruned_row_is_zero_not_nan() {
        // Regression: a row of -inf (or any row whose exponentials all
        // underflow) used to normalize by sum == 0 and fill with NaN.
        let a = Tensor::new(
            &[2, 3],
            vec![f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY, //
                 1.0, 2.0, 3.0],
        );
        let s = a.softmax_rows();
        assert_eq!(s.row(0), &[0.0, 0.0, 0.0]);
        assert!(s.row(0).iter().all(|p| !p.is_nan()));
        assert!((s.row(1).iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn matmul_nt_into_matches_matmul_nt_bitwise() {
        // The blocked microkernel must agree bit-for-bit with the
        // allocating entry point on awkward (non-multiple-of-tile)
        // shapes.
        for (m, n, k) in [(1usize, 1usize, 1usize), (3, 5, 7), (4, 4, 16),
                          (9, 6, 13), (17, 33, 8)] {
            let a = randt(&[m, k], (m * 100 + n) as u64);
            let b = randt(&[n, k], (n * 100 + k) as u64);
            let want = a.matmul_nt(&b);
            let mut out = vec![9.9f32; m * n];
            a.matmul_nt_into(&b, &mut out);
            assert_eq!(out, want.data(), "shape {m}x{n}x{k}");
        }
    }

    #[test]
    #[should_panic(expected = "out len")]
    fn matmul_nt_into_checks_out_len() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 3]);
        let mut out = vec![0.0; 7];
        a.matmul_nt_into(&b, &mut out);
    }

    #[test]
    fn softmax_handles_neg_inf_sentinels() {
        let a = Tensor::new(&[1, 4], vec![1.0, -1e9, 2.0, -1e9]);
        let s = a.softmax_rows();
        assert!(s.at(0, 1) < 1e-12 && s.at(0, 3) < 1e-12);
        assert!((s.row(0).iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn prop_matmul_linear_in_scalar() {
        check("matmul(c*a, b) == c*matmul(a,b)", 50, |g| {
            let m = g.usize(1, 6);
            let k = g.usize(1, 6);
            let n = g.usize(1, 6);
            let c = g.f32(-3.0, 3.0);
            let mut r = SplitMix64::new(g.u64(0, u64::MAX / 2));
            let a = Tensor::from_fn(&[m, k], |_| r.next_normal() as f32);
            let b = Tensor::from_fn(&[k, n], |_| r.next_normal() as f32);
            let lhs = a.scale(c).matmul(&b);
            let rhs = a.matmul(&b).scale(c);
            prop_assert_close(
                lhs.max_abs_diff(&rhs) as f64, 0.0, 1e-4, "linearity")
        });
    }

    #[test]
    fn prop_softmax_invariant_to_shift() {
        check("softmax(x + c) == softmax(x)", 50, |g| {
            let n = g.usize(2, 16);
            let c = g.f32(-5.0, 5.0);
            let mut r = SplitMix64::new(g.u64(0, u64::MAX / 2));
            let a = Tensor::from_fn(&[1, n], |_| r.next_normal() as f32);
            let b = a.map(|x| x + c);
            prop_assert_close(
                a.softmax_rows().max_abs_diff(&b.softmax_rows()) as f64,
                0.0,
                1e-5,
                "shift invariance",
            )
        });
    }
}
