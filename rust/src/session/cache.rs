//! Block-granular KV cache state for one attention head of one
//! session.
//!
//! [`HeadKv`] holds everything an incremental decode step needs so the
//! per-token cost stays `O(l·d)` instead of the full-recompute
//! `O(l²·d)`:
//!
//! * **Pages** — the quantized key fields `IK`/`FK`, the float values
//!   `V`, and the integer query field `IQ` of every cached token, in
//!   fixed-capacity pages (`page_tokens` rows each, a multiple of
//!   the pruning block edge — block-aligned growth). Appending a token
//!   touches at most one page; a new page is allocated only when the
//!   last one fills. `IQ` is cached because a *new key column* scores
//!   against every *old query row* (the attention here is
//!   bidirectional, as in the reference); `FQ` is not cached — the
//!   fraction field of a query is only ever used by its own decode
//!   step's FUM stage.
//! * **θ matrix** — the block importances over the whole cached
//!   context, maintained incrementally in **exactly the accumulation
//!   order of [`crate::attention::hdp::block_importance`]** so the
//!   decode path's pruning decisions (row threshold Θ, head statistic
//!   `theta_head`) are bitwise identical to a full recompute. See
//!   [`HeadKv::update_theta`] for the order argument.
//! * **Tail columns** — `|integer score|` columns of the partial
//!   (growing) tail block-column. A θ cell crossed by a growing block
//!   *column* cannot be appended to in reference order (the reference
//!   interleaves old and new entries), so those `≤ block` cells per
//!   block-row are recomputed from these retained columns each step
//!   and the buffer is dropped the moment the block-column completes.
//!
//! The θ matrix and tail columns above describe the default
//! **bidirectional** mode, whose per-head θ cost is O(nb²). A head
//! created with [`SessionMode::Causal`] instead keeps **row-only θ
//! statistics** — the current block-row plus one frozen `theta_head`
//! prefix scalar, O(nb) total — because under a causal mask a new key
//! column never scores against older query rows, so no completed θ
//! cell can ever change. See [`HeadKv::update_theta_causal`] for the
//! accumulation-order argument; the conformance anchor is
//! [`crate::attention::hdp::hdp_causal_reference`].
//!
//! The decode math itself (scoring, threshold, FUM, softmax, P·V)
//! lives in [`crate::attention::kernel`] (`MhaKernel::decode_step`);
//! this type owns the state and its growth/bookkeeping invariants.
//! [`KvCache`] aggregates the `layers × heads` grid of [`HeadKv`]s
//! that one session owns, each behind its own `Mutex` so independent
//! heads decode in parallel without contention.

use std::sync::Mutex;

use crate::attention::hdp::n_blocks;

/// How a session's decode steps attend to their cached context — fixed
/// at the session's first request and checked on every later step.
///
/// * [`SessionMode::Bidirectional`] (the default) is the repo's spine:
///   every step is bitwise identical to
///   [`crate::attention::hdp::hdp_head_reference`] full recompute. Its
///   θ matrix costs O(nb²) per head.
/// * [`SessionMode::Causal`] is the explicitly-selected long-context
///   mode: token `i` attends to keys `j <= i` (and `j >= i + 1 - w`
///   when `window = Some(w)`), pinned bitwise against
///   [`crate::attention::hdp::hdp_causal_reference`]. Only the current
///   block-row of θ plus one frozen prefix scalar are kept — O(nb)
///   per head — which is what makes 8k+ contexts affordable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SessionMode {
    #[default]
    Bidirectional,
    /// Causal decode; `window = Some(w)` additionally restricts each
    /// query to the `w` most recent keys (its own included).
    Causal { window: Option<usize> },
}

impl SessionMode {
    pub fn is_causal(&self) -> bool {
        matches!(self, SessionMode::Causal { .. })
    }

    /// The attention window, if this mode restricts one.
    pub fn window(&self) -> Option<usize> {
        match self {
            SessionMode::Bidirectional => None,
            SessionMode::Causal { window } => *window,
        }
    }
}

impl std::fmt::Display for SessionMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionMode::Bidirectional => write!(f, "bidirectional"),
            SessionMode::Causal { window: None } => write!(f, "causal"),
            SessionMode::Causal { window: Some(w) } => write!(f, "causal/w{w}"),
        }
    }
}

/// One token's derived attention-row fields on the quant grid:
/// quantized query/key integer+fraction fields (`d_head` each) plus
/// the float value row (`d_v`). This is the unit a decode step appends
/// to the cache.
#[derive(Debug, Clone, Default)]
pub struct TokenRow {
    pub iq: Vec<f32>,
    pub fq: Vec<f32>,
    pub ik: Vec<f32>,
    pub fk: Vec<f32>,
    pub v: Vec<f32>,
}

/// One fixed-capacity page of cached token rows (`page_tokens` rows of
/// `iq`/`ik`/`fk` at `d_head` and `v` at `d_v`). Buffers are allocated
/// once at page creation; rows fill in append order.
#[derive(Debug, Clone)]
struct Page {
    used: usize,
    iq: Vec<f32>,
    ik: Vec<f32>,
    fk: Vec<f32>,
    v: Vec<f32>,
}

impl Page {
    fn new(page_tokens: usize, d_head: usize, d_v: usize) -> Self {
        Self {
            used: 0,
            iq: vec![0.0; page_tokens * d_head],
            ik: vec![0.0; page_tokens * d_head],
            fk: vec![0.0; page_tokens * d_head],
            v: vec![0.0; page_tokens * d_v],
        }
    }
}

/// Per-(session, layer, head) cached decode state. See the module docs
/// for the layout and the bitwise-exactness argument.
#[derive(Debug)]
pub struct HeadKv {
    d_head: usize,
    d_v: usize,
    block: usize,
    page_tokens: usize,
    mode: SessionMode,
    len: usize,
    pages: Vec<Page>,
    /// θ rows, one `Vec` per block-row, every row `n_blocks(len)` long.
    /// Row-major iteration reproduces the reference's flat layout.
    /// Bidirectional mode only — stays empty in causal mode.
    theta: Vec<Vec<f32>>,
    /// `|integer score|` columns of the partial tail block-column
    /// (column-major, ascending column index; each column holds `len`
    /// entries). Empty whenever `len` is block-aligned. Bidirectional
    /// mode only.
    tail_abs: Vec<Vec<f32>>,
    /// Causal mode's whole θ state, part 1: the θ row of the *current*
    /// (growing) block-row, `n_blocks(len)` cells. O(nb).
    causal_row: Vec<f32>,
    /// Causal mode's whole θ state, part 2: the running flat row-major
    /// fold of every *completed* block-row's θ cells — exactly the
    /// single-accumulator state the reference's `theta_head` sum
    /// reaches after those rows (trailing zero cells added as nb grows
    /// later are bitwise no-ops: every θ term is an `abs()` so the
    /// accumulator is ≥ +0.0, and `x + 0.0 == x` bitwise there).
    causal_frozen: f32,
}

impl HeadKv {
    pub fn new(d_head: usize, d_v: usize, block: usize, page_tokens: usize) -> Self {
        Self::with_mode(d_head, d_v, block, page_tokens, SessionMode::Bidirectional)
    }

    /// Like [`HeadKv::new`] but with an explicit [`SessionMode`]; the
    /// mode is fixed for the head's lifetime (a session never changes
    /// mode mid-stream — the store refuses such steps upstream).
    pub fn with_mode(
        d_head: usize,
        d_v: usize,
        block: usize,
        page_tokens: usize,
        mode: SessionMode,
    ) -> Self {
        assert!(d_head > 0 && d_v > 0 && block > 0, "degenerate head geometry");
        assert!(
            page_tokens > 0 && page_tokens % block == 0,
            "page_tokens {page_tokens} must be a positive multiple of block {block}"
        );
        Self {
            d_head,
            d_v,
            block,
            page_tokens,
            mode,
            len: 0,
            pages: Vec::new(),
            theta: Vec::new(),
            tail_abs: Vec::new(),
            causal_row: Vec::new(),
            causal_frozen: 0.0,
        }
    }

    /// The attention mode this head was created for.
    pub fn mode(&self) -> SessionMode {
        self.mode
    }

    /// Cached context length in tokens.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn d_head(&self) -> usize {
        self.d_head
    }

    pub fn d_v(&self) -> usize {
        self.d_v
    }

    pub fn block(&self) -> usize {
        self.block
    }

    /// Pages currently allocated (the unit capacity accounting and
    /// eviction work in).
    pub fn pages(&self) -> usize {
        self.pages.len()
    }

    /// Blocks covering the cached context (ceil — the tail may be
    /// partial).
    pub fn n_blocks_ctx(&self) -> usize {
        n_blocks(self.len, self.block)
    }

    /// Append one token's fields to the pages (block-aligned growth: a
    /// new page only when the last one filled). The θ state is *not*
    /// updated here — the kernel scores the new row first and then
    /// calls [`HeadKv::update_theta`] with those scores.
    pub fn append(&mut self, row: &TokenRow) {
        assert_eq!(row.iq.len(), self.d_head, "iq row width");
        assert_eq!(row.ik.len(), self.d_head, "ik row width");
        assert_eq!(row.fk.len(), self.d_head, "fk row width");
        assert_eq!(row.v.len(), self.d_v, "v row width");
        if self.len == self.pages.len() * self.page_tokens {
            self.pages.push(Page::new(self.page_tokens, self.d_head, self.d_v));
        }
        let page = self.pages.last_mut().expect("page just ensured");
        let (r, dh, dv) = (page.used, self.d_head, self.d_v);
        page.iq[r * dh..(r + 1) * dh].copy_from_slice(&row.iq);
        page.ik[r * dh..(r + 1) * dh].copy_from_slice(&row.ik);
        page.fk[r * dh..(r + 1) * dh].copy_from_slice(&row.fk);
        page.v[r * dv..(r + 1) * dv].copy_from_slice(&row.v);
        page.used += 1;
        self.len += 1;
    }

    #[inline]
    fn page_row(&self, i: usize) -> (&Page, usize) {
        debug_assert!(i < self.len, "row {i} past cached length {}", self.len);
        (&self.pages[i / self.page_tokens], i % self.page_tokens)
    }

    /// Cached integer query row `i`.
    #[inline]
    pub fn iq_row(&self, i: usize) -> &[f32] {
        let (p, r) = self.page_row(i);
        &p.iq[r * self.d_head..(r + 1) * self.d_head]
    }

    /// Cached integer key row `j`.
    #[inline]
    pub fn ik_row(&self, j: usize) -> &[f32] {
        let (p, r) = self.page_row(j);
        &p.ik[r * self.d_head..(r + 1) * self.d_head]
    }

    /// Cached fraction key row `j`.
    #[inline]
    pub fn fk_row(&self, j: usize) -> &[f32] {
        let (p, r) = self.page_row(j);
        &p.fk[r * self.d_head..(r + 1) * self.d_head]
    }

    /// Cached value row `j`.
    #[inline]
    pub fn v_row(&self, j: usize) -> &[f32] {
        let (p, r) = self.page_row(j);
        &p.v[r * self.d_v..(r + 1) * self.d_v]
    }

    /// Fold the newest token's integer scores into θ, preserving the
    /// reference accumulation order exactly. Call once per appended
    /// token, *after* [`HeadKv::append`], with
    ///
    /// * `s_row_abs[j] = |IQ_r · IK_j|` for `j in 0..len` (the new
    ///   query row against every cached key, diagonal included), and
    /// * `col_abs[i] = |IQ_i · IK_r|` for `i in 0..len-1` (every older
    ///   query row against the new key column),
    ///
    /// where `r = len - 1` is the newest row.
    ///
    /// Why this is bitwise exact: the reference
    /// (`block_importance_into`) fills a θ cell by scanning score rows
    /// `i` ascending and, within a row, columns `j` ascending. A cell
    /// in the growing block-*row* only ever gains entries from the new
    /// row `r`, which is the largest `i` in its block — appending its
    /// `|s|` terms (ascending `j`) to the running cell extends the
    /// reference fold at its end, so the float result is identical. A
    /// cell crossed by the growing block-*column* would need new terms
    /// interleaved into the middle of the fold, which no incremental
    /// update can do — so every cell of the partial tail block-column
    /// is recomputed from scratch, in reference order, from the
    /// retained `tail_abs` columns (at most `block` columns, dropped
    /// once the block-column completes).
    pub fn update_theta(&mut self, s_row_abs: &[f32], col_abs: &[f32]) {
        let l = self.len;
        assert!(l > 0, "update_theta before first append");
        let r = l - 1;
        let b = self.block;
        assert_eq!(s_row_abs.len(), l, "score row length");
        assert_eq!(col_abs.len(), r, "score column length");
        let (br, nb) = (r / b, n_blocks(l, b));

        // Grow the θ matrix: a new block-row and block-column appear
        // together (the score matrix is square) when `r` opens a block.
        if self.theta.len() < nb {
            self.theta.push(vec![0.0; nb]);
        }
        for row in &mut self.theta {
            row.resize(nb, 0.0);
        }

        // Completed block-columns of the growing block-row: append the
        // new row's terms at the end of each cell's fold (ascending j).
        for bj in 0..br {
            let cell = &mut self.theta[br][bj];
            for &s in &s_row_abs[bj * b..(bj + 1) * b] {
                *cell += s;
            }
        }

        // Tail block-column bookkeeping: extend the retained columns
        // with the new row's entries, then add the new column itself.
        if r % b == 0 {
            self.tail_abs.clear(); // `r` opened a fresh block-column
        } else {
            for (t, col) in self.tail_abs.iter_mut().enumerate() {
                col.push(s_row_abs[br * b + t]);
            }
        }
        let mut col = Vec::with_capacity(l);
        col.extend_from_slice(col_abs);
        col.push(s_row_abs[r]); // the diagonal entry
        self.tail_abs.push(col);

        // Recompute every cell of the tail block-column in reference
        // order (i ascending, then j ascending across the columns).
        for bi in 0..nb {
            let (i0, i1) = (bi * b, ((bi + 1) * b).min(l));
            let mut acc = 0.0f32;
            for i in i0..i1 {
                for tail_col in &self.tail_abs {
                    acc += tail_col[i];
                }
            }
            self.theta[bi][br] = acc;
        }

        // Block-column complete: its cells are final, drop the scores.
        if l % b == 0 {
            self.tail_abs.clear();
        }
    }

    /// Causal-mode θ fold for the newest token. Call once per appended
    /// token, *after* [`HeadKv::append`], with the in-window score
    /// magnitudes of the new query row:
    /// `s_abs[k] = |IQ_r · IK_{lo+k}|` for `lo + k in lo..len`, where
    /// `lo = (r + 1).saturating_sub(window)` (`lo = 0` unwindowed) and
    /// `r = len - 1`.
    ///
    /// Why this is bitwise identical to [`crate::attention::hdp::
    /// hdp_causal_reference`]'s θ (which masks out-of-window score
    /// cells to zero and then runs the full `block_importance` fold):
    ///
    /// * A new key column is masked for every *older* query row
    ///   (`j = r > i`), so unlike the bidirectional path no θ cell
    ///   above the current block-row ever changes — there is no tail
    ///   block-column to repair and nothing to retain beyond the
    ///   current block-row itself.
    /// * Within the current block-row, the reference folds score rows
    ///   `i` ascending and columns `j` ascending; the new row `r` is
    ///   the largest `i` in its block, so appending its in-window
    ///   terms (ascending `j`) extends each cell's fold at the end.
    /// * The reference's masked cells contribute `+0.0` in place;
    ///   skipping them entirely is the same fold bit for bit because
    ///   every partial sum of `abs()` terms is ≥ +0.0 and IEEE-754
    ///   `x + (+0.0) == x` bitwise there
    ///   (`prop_zero_fold_is_bitwise_noop_for_abs_accumulation` in
    ///   `attention::hdp` pins the argument).
    ///
    /// When a later token opens a new block-row, the completed row is
    /// folded (ascending `bj`) into the frozen prefix scalar — the
    /// accumulation order of the reference's flat row-major
    /// `theta_head` sum — and the live row resets. Total state: one
    /// `nb`-cell row plus one scalar, O(nb).
    pub fn update_theta_causal(&mut self, lo: usize, s_abs: &[f32]) {
        assert!(self.mode.is_causal(), "causal update on {} head", self.mode);
        let l = self.len;
        assert!(l > 0, "update_theta_causal before first append");
        let r = l - 1;
        let b = self.block;
        assert_eq!(s_abs.len(), l - lo, "windowed score row length");
        let nb = n_blocks(l, b);
        if r % b == 0 && r > 0 {
            // `r` opened a new block-row: the previous one is complete
            // and final — fold it into the frozen theta_head prefix in
            // flat row-major order, then reset the live row.
            for &t in &self.causal_row {
                self.causal_frozen += t;
            }
            self.causal_row.clear();
        }
        self.causal_row.resize(nb, 0.0);
        for (k, &s) in s_abs.iter().enumerate() {
            self.causal_row[(lo + k) / b] += s;
        }
    }

    /// θ row of the *current* block-row in causal mode — the row the
    /// newest query thresholds (full `nb` width, trailing zeros
    /// included, exactly like the reference's `block_mask` row).
    pub fn theta_row_causal(&self) -> &[f32] {
        &self.causal_row
    }

    /// Causal-mode head statistic: the frozen prefix continued through
    /// the live row — bitwise identical to the reference's flat
    /// row-major `theta.data().iter().sum()`.
    pub fn theta_head_causal(&self) -> f32 {
        let mut acc = self.causal_frozen;
        for &t in &self.causal_row {
            acc += t;
        }
        acc
    }

    /// Live θ-statistic cells this head holds — the quantity the mode
    /// memory guarantee is stated in: O(nb²) bidirectional (θ matrix +
    /// partial tail columns), O(nb) causal (one block-row + a scalar).
    pub fn theta_cells(&self) -> usize {
        self.theta.iter().map(Vec::len).sum::<usize>()
            + self.tail_abs.iter().map(Vec::len).sum::<usize>()
            + self.causal_row.len()
    }

    /// θ row of block-row `bi` (what the decode step thresholds for
    /// the newest query).
    pub fn theta_row(&self, bi: usize) -> &[f32] {
        &self.theta[bi]
    }

    /// The head statistic: θ summed in the reference's flat row-major
    /// order (single `f32` accumulator, bitwise identical to
    /// `theta.data().iter().sum()` over the recomputed matrix).
    pub fn theta_head(&self) -> f32 {
        let mut acc = 0.0f32;
        for row in &self.theta {
            for &t in row {
                acc += t;
            }
        }
        acc
    }

    /// Deep copy of the full head state — pages, θ matrix, and the
    /// partial tail-column scores. A snapshot restored later continues
    /// decoding bitwise identically to the head it was taken from,
    /// because every field that feeds the incremental θ fold is copied
    /// verbatim (the fold order is a function of state, not identity).
    pub fn snapshot(&self) -> HeadKv {
        HeadKv {
            d_head: self.d_head,
            d_v: self.d_v,
            block: self.block,
            page_tokens: self.page_tokens,
            mode: self.mode,
            len: self.len,
            pages: self.pages.clone(),
            theta: self.theta.clone(),
            tail_abs: self.tail_abs.clone(),
            causal_row: self.causal_row.clone(),
            causal_frozen: self.causal_frozen,
        }
    }
}

/// One session's cache: the `layers × heads` grid of [`HeadKv`]s, each
/// behind its own `Mutex` so a decode step can fan independent heads
/// across worker threads (disjoint locks — no contention, and
/// determinism is untouched because heads never read each other).
#[derive(Debug)]
pub struct KvCache {
    n_layers: usize,
    n_heads: usize,
    mode: SessionMode,
    heads: Vec<Mutex<HeadKv>>,
}

impl KvCache {
    pub fn new(
        n_layers: usize,
        n_heads: usize,
        d_head: usize,
        d_v: usize,
        block: usize,
        page_tokens: usize,
    ) -> Self {
        Self::with_mode(
            n_layers,
            n_heads,
            d_head,
            d_v,
            block,
            page_tokens,
            SessionMode::Bidirectional,
        )
    }

    /// Like [`KvCache::new`] but every head is created in `mode`.
    pub fn with_mode(
        n_layers: usize,
        n_heads: usize,
        d_head: usize,
        d_v: usize,
        block: usize,
        page_tokens: usize,
        mode: SessionMode,
    ) -> Self {
        assert!(n_layers > 0 && n_heads > 0, "degenerate cache geometry");
        let heads = (0..n_layers * n_heads)
            .map(|_| Mutex::new(HeadKv::with_mode(d_head, d_v, block, page_tokens, mode)))
            .collect();
        Self { n_layers, n_heads, mode, heads }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    /// The attention mode every head in the grid was created for.
    pub fn mode(&self) -> SessionMode {
        self.mode
    }

    /// Live θ-statistic cells across the grid — what the causal-mode
    /// O(nb) memory test asserts on.
    pub fn theta_cells(&self) -> usize {
        self.heads.iter().map(|h| h.lock().unwrap().theta_cells()).sum()
    }

    /// The (layer, head) cell. Lock order never matters: a decode step
    /// locks each head exactly once, disjointly.
    pub fn head(&self, layer: usize, head: usize) -> &Mutex<HeadKv> {
        &self.heads[layer * self.n_heads + head]
    }

    /// Cached context length (every head advances in lockstep).
    pub fn len(&self) -> usize {
        self.heads[0].lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total pages allocated across the grid — the store's capacity
    /// accounting unit.
    pub fn pages(&self) -> usize {
        self.heads.iter().map(|h| h.lock().unwrap().pages()).sum()
    }

    /// Deep copy of the whole `layers × heads` grid (a frozen
    /// checkpoint). Locks each head once, disjointly, so a snapshot
    /// may be taken while other sessions decode; the caller must not
    /// be mid-append on *this* session (heads advance in lockstep, so
    /// snapshot between decode steps, never inside one).
    pub fn snapshot(&self) -> KvCache {
        KvCache {
            n_layers: self.n_layers,
            n_heads: self.n_heads,
            mode: self.mode,
            heads: self
                .heads
                .iter()
                .map(|h| Mutex::new(h.lock().unwrap().snapshot()))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::hdp::block_importance;
    use crate::tensor::Tensor;
    use crate::util::prop::{check, prop_assert};
    use crate::util::rng::SplitMix64;

    fn rand_row(rng: &mut SplitMix64, dh: usize, dv: usize) -> TokenRow {
        // Integer-ish quantized fields so scores are exact; θ order
        // still matters because |s| folds in f32.
        fn quant(rng: &mut SplitMix64, n: usize) -> Vec<f32> {
            (0..n).map(|_| (rng.next_normal() as f32 * 2.0).round()).collect()
        }
        fn frac(rng: &mut SplitMix64, n: usize) -> Vec<f32> {
            (0..n).map(|_| rng.next_normal() as f32 * 0.25).collect()
        }
        let iq = quant(rng, dh);
        let fq = frac(rng, dh);
        let ik = quant(rng, dh);
        let fk = frac(rng, dh);
        let v = frac(rng, dv);
        TokenRow { iq, fq, ik, fk, v }
    }

    fn dot(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = 0.0f32;
        for (x, y) in a.iter().zip(b) {
            acc += x * y;
        }
        acc
    }

    /// Drive the per-step θ update the way the kernel does.
    fn append_and_update(kv: &mut HeadKv, row: &TokenRow) {
        kv.append(row);
        let l = kv.len();
        let r = l - 1;
        let s_row_abs: Vec<f32> =
            (0..l).map(|j| dot(&row.iq, kv.ik_row(j)).abs()).collect();
        let col_abs: Vec<f32> =
            (0..r).map(|i| dot(kv.iq_row(i), kv.ik_row(r)).abs()).collect();
        kv.update_theta(&s_row_abs, &col_abs);
    }

    /// Drive the causal per-step θ update the way the kernel does:
    /// dots only for the in-window keys, no column scores at all.
    fn append_and_update_causal(kv: &mut HeadKv, row: &TokenRow, window: Option<usize>) {
        kv.append(row);
        let l = kv.len();
        let lo = window.map_or(0, |w| l.saturating_sub(w));
        let s_abs: Vec<f32> =
            (lo..l).map(|j| dot(&row.iq, kv.ik_row(j)).abs()).collect();
        kv.update_theta_causal(lo, &s_abs);
    }

    #[test]
    fn pages_grow_block_aligned() {
        let mut rng = SplitMix64::new(7);
        let mut kv = HeadKv::new(4, 4, 2, 8);
        assert_eq!(kv.pages(), 0);
        for t in 1..=25 {
            append_and_update(&mut kv, &rand_row(&mut rng, 4, 4));
            assert_eq!(kv.len(), t);
            assert_eq!(kv.pages(), t.div_euclid(8) + usize::from(t % 8 != 0));
            assert_eq!(kv.n_blocks_ctx(), t / 2 + t % 2);
        }
        assert_eq!(kv.pages(), 4); // 25 tokens over 8-token pages
    }

    #[test]
    #[should_panic(expected = "multiple of block")]
    fn page_size_must_align_to_block() {
        HeadKv::new(4, 4, 2, 7);
    }

    #[test]
    fn rows_read_back_across_page_boundaries() {
        let mut rng = SplitMix64::new(9);
        let rows: Vec<TokenRow> =
            (0..10).map(|_| rand_row(&mut rng, 3, 5)).collect();
        let mut kv = HeadKv::new(3, 5, 2, 4);
        for row in &rows {
            append_and_update(&mut kv, row);
        }
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(kv.iq_row(i), &row.iq[..], "iq row {i}");
            assert_eq!(kv.ik_row(i), &row.ik[..], "ik row {i}");
            assert_eq!(kv.fk_row(i), &row.fk[..], "fk row {i}");
            assert_eq!(kv.v_row(i), &row.v[..], "v row {i}");
        }
    }

    #[test]
    fn prop_incremental_theta_matches_reference_bitwise() {
        // The load-bearing invariant of the whole decode path: after
        // every single append, the incrementally maintained θ matrix —
        // and the flat-summed head statistic — are bitwise identical
        // to `block_importance` recomputed from scratch over the full
        // stacked context.
        check("incremental theta == block_importance (bitwise)", 20, |g| {
            let dh = *g.choice(&[3usize, 8]);
            let block = *g.choice(&[1usize, 2, 4]);
            let steps = g.usize(1, 17);
            let mut rng = SplitMix64::new(g.u64(0, u64::MAX / 2));
            let mut kv = HeadKv::new(dh, 4, block, 4 * block);
            let mut rows: Vec<TokenRow> = Vec::new();
            for _ in 0..steps {
                let row = rand_row(&mut rng, dh, 4);
                append_and_update(&mut kv, &row);
                rows.push(row);
                let l = rows.len();
                let mut iq_data = Vec::with_capacity(l * dh);
                let mut ik_data = Vec::with_capacity(l * dh);
                for r in &rows {
                    iq_data.extend_from_slice(&r.iq);
                    ik_data.extend_from_slice(&r.ik);
                }
                let iq = Tensor::new(&[l, dh], iq_data);
                let ik = Tensor::new(&[l, dh], ik_data);
                let want = block_importance(&iq.matmul_nt(&ik), block);
                let nb = kv.n_blocks_ctx();
                prop_assert(want.rows() == nb, "theta rows")?;
                for bi in 0..nb {
                    let got = kv.theta_row(bi);
                    let exp = want.row(bi);
                    for (bj, (a, b)) in got.iter().zip(exp).enumerate() {
                        prop_assert(
                            a.to_bits() == b.to_bits(),
                            format!("theta[{bi}][{bj}] {a} != {b} at l={l}"),
                        )?;
                    }
                }
                let mut flat = 0.0f32;
                for &t in want.data() {
                    flat += t;
                }
                prop_assert(
                    kv.theta_head().to_bits() == flat.to_bits(),
                    format!("theta_head at l={l}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_causal_row_theta_matches_causal_reference_bitwise() {
        // The causal-mode counterpart of the invariant above, against
        // the causal reference's θ accumulation: mask the integer score
        // outside the causal window to zero, run the *full*
        // `block_importance` fold — the O(nb) row-only state must agree
        // bitwise at every length, ragged mid-block and block-aligned
        // alike, and hold exactly nb live θ cells while doing so.
        use crate::attention::hdp::causal_in_window;
        check("causal row theta == masked block_importance (bitwise)", 20, |g| {
            let dh = *g.choice(&[3usize, 8]);
            let block = *g.choice(&[1usize, 2, 4]);
            let steps = g.usize(1, 17);
            let window = *g.choice(&[None, Some(1usize), Some(3), Some(8), Some(256)]);
            let mut rng = SplitMix64::new(g.u64(0, u64::MAX / 2));
            let mode = SessionMode::Causal { window };
            let mut kv = HeadKv::with_mode(dh, 4, block, 4 * block, mode);
            let mut rows: Vec<TokenRow> = Vec::new();
            for _ in 0..steps {
                let row = rand_row(&mut rng, dh, 4);
                append_and_update_causal(&mut kv, &row, window);
                rows.push(row);
                let l = rows.len();
                let mut iq_data = Vec::with_capacity(l * dh);
                let mut ik_data = Vec::with_capacity(l * dh);
                for r in &rows {
                    iq_data.extend_from_slice(&r.iq);
                    ik_data.extend_from_slice(&r.ik);
                }
                let iq = Tensor::new(&[l, dh], iq_data);
                let ik = Tensor::new(&[l, dh], ik_data);
                let mut s = iq.matmul_nt(&ik);
                for i in 0..l {
                    for j in 0..l {
                        if !causal_in_window(i, j, window) {
                            s.set(i, j, 0.0);
                        }
                    }
                }
                let want = block_importance(&s, block);
                let br = (l - 1) / block;
                let got = kv.theta_row_causal();
                prop_assert(got.len() == want.cols(), "row width")?;
                for (bj, (a, b)) in got.iter().zip(want.row(br)).enumerate() {
                    prop_assert(
                        a.to_bits() == b.to_bits(),
                        format!("causal theta[{br}][{bj}] {a} != {b} at l={l}"),
                    )?;
                }
                let mut flat = 0.0f32;
                for &t in want.data() {
                    flat += t;
                }
                prop_assert(
                    kv.theta_head_causal().to_bits() == flat.to_bits(),
                    format!("causal theta_head at l={l}"),
                )?;
                prop_assert(
                    kv.theta_cells() == want.cols(),
                    format!("O(nb) cells: {} != {}", kv.theta_cells(), want.cols()),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn causal_8k_context_holds_o_nb_theta_cells() {
        // The acceptance bound of the causal mode: at 8k context the
        // live θ state is exactly nb cells (one block-row) per head —
        // linear in context — where the bidirectional matrix would hold
        // nb² + tail cells (~16.8M at block 2). Windowed so the test's
        // scoring work stays O(l·w) and the suite stays fast.
        let window = Some(256);
        let mode = SessionMode::Causal { window };
        let mut rng = SplitMix64::new(41);
        let mut kv = HeadKv::with_mode(3, 4, 2, 64, mode);
        for _ in 0..8192 {
            append_and_update_causal(&mut kv, &rand_row(&mut rng, 3, 4), window);
        }
        assert_eq!(kv.len(), 8192);
        let nb = kv.n_blocks_ctx();
        assert_eq!(nb, 4096);
        assert_eq!(kv.theta_cells(), nb, "row-only state is O(nb)");
        assert_eq!(kv.pages(), 8192 / 64);
    }

    #[test]
    fn causal_snapshot_restores_bitwise_identical_decode_state() {
        // Snapshot mid-stream in causal mode (including mid-block, so
        // the live row and the frozen prefix are both nontrivial), keep
        // appending to both copies: θ row and head statistic must stay
        // bitwise equal — the spill/restore and checkpoint contract.
        let window = Some(5);
        let mode = SessionMode::Causal { window };
        let mut rng = SplitMix64::new(33);
        let rows: Vec<TokenRow> =
            (0..13).map(|_| rand_row(&mut rng, 4, 4)).collect();
        let mut kv = HeadKv::with_mode(4, 4, 2, 4, mode);
        for row in &rows[..7] {
            append_and_update_causal(&mut kv, row, window);
        }
        let mut restored = kv.snapshot();
        assert_eq!(restored.len(), 7);
        assert_eq!(restored.mode(), mode);
        for row in &rows[7..] {
            append_and_update_causal(&mut kv, row, window);
            append_and_update_causal(&mut restored, row, window);
        }
        assert_eq!(restored.len(), kv.len());
        for (a, b) in kv.theta_row_causal().iter().zip(restored.theta_row_causal()) {
            assert_eq!(a.to_bits(), b.to_bits(), "live causal theta row");
        }
        assert_eq!(
            kv.theta_head_causal().to_bits(),
            restored.theta_head_causal().to_bits()
        );
        for i in 0..kv.len() {
            assert_eq!(kv.ik_row(i), restored.ik_row(i), "ik row {i}");
            assert_eq!(kv.v_row(i), restored.v_row(i), "v row {i}");
        }
    }

    #[test]
    fn snapshot_restores_bitwise_identical_decode_state() {
        // Take a snapshot mid-stream, keep appending to both the
        // original and the snapshot with the same rows: every θ cell
        // and the head statistic must stay bitwise equal — the
        // checkpointed-restore contract of `session::journal`.
        let mut rng = SplitMix64::new(21);
        let rows: Vec<TokenRow> =
            (0..13).map(|_| rand_row(&mut rng, 4, 4)).collect();
        let mut kv = HeadKv::new(4, 4, 2, 4);
        for row in &rows[..7] {
            append_and_update(&mut kv, row);
        }
        let mut restored = kv.snapshot();
        assert_eq!(restored.len(), 7);
        assert_eq!(restored.pages(), kv.pages());
        for row in &rows[7..] {
            append_and_update(&mut kv, row);
            append_and_update(&mut restored, row);
        }
        assert_eq!(restored.len(), kv.len());
        for bi in 0..kv.n_blocks_ctx() {
            for (a, b) in kv.theta_row(bi).iter().zip(restored.theta_row(bi)) {
                assert_eq!(a.to_bits(), b.to_bits(), "theta block-row {bi}");
            }
        }
        assert_eq!(kv.theta_head().to_bits(), restored.theta_head().to_bits());
        for i in 0..kv.len() {
            assert_eq!(kv.ik_row(i), restored.ik_row(i), "ik row {i}");
            assert_eq!(kv.v_row(i), restored.v_row(i), "v row {i}");
        }
    }

    #[test]
    fn kv_cache_snapshot_is_independent() {
        let cache = KvCache::new(2, 2, 4, 4, 2, 4);
        let mut rng = SplitMix64::new(5);
        let row = rand_row(&mut rng, 4, 4);
        for layer in 0..2 {
            for head in 0..2 {
                cache.head(layer, head).lock().unwrap().append(&row);
            }
        }
        let snap = cache.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap.pages(), cache.pages());
        // Growing the original must not disturb the frozen snapshot.
        for layer in 0..2 {
            for head in 0..2 {
                cache.head(layer, head).lock().unwrap().append(&row);
            }
        }
        assert_eq!(cache.len(), 2);
        assert_eq!(snap.len(), 1, "snapshot is a deep copy");
    }

    #[test]
    fn kv_cache_grid_and_page_accounting() {
        let cache = KvCache::new(2, 3, 4, 4, 2, 4);
        assert!(cache.is_empty());
        let mut rng = SplitMix64::new(3);
        let row = rand_row(&mut rng, 4, 4);
        for layer in 0..2 {
            for head in 0..3 {
                cache.head(layer, head).lock().unwrap().append(&row);
            }
        }
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.pages(), 6, "one page per head after first token");
    }
}
