//! Session subsystem: block-sparse KV caching for incremental decode.
//!
//! One-shot serving recomputes every K/V from scratch per request; the
//! autoregressive decode workload — where HDP's *runtime* block
//! pruning pays off most — instead attends over a growing cached
//! context, pruned block-by-block each step. This module is that
//! state:
//!
//! * [`cache::HeadKv`] — per-(session, layer, head) paged K/V on the
//!   quant grid plus the incrementally maintained θ state, kept in
//!   exactly the reference accumulation order so every decode step is
//!   bitwise identical to a full recompute
//!   ([`crate::attention::hdp::hdp_head_reference`] over the whole
//!   context).
//! * [`cache::KvCache`] — one session's `layers × heads` grid of
//!   `HeadKv`s (per-head `Mutex`es: disjoint parallel decode).
//! * [`cache::SessionMode`] — how a session attends: the default
//!   bidirectional mode (O(nb²) θ, pinned against
//!   `hdp_head_reference`) or the explicitly-selected causal/windowed
//!   mode (row-only O(nb) θ, pinned against
//!   [`crate::attention::hdp::hdp_causal_reference`]). Fixed at the
//!   session's first request; a later step naming the wrong mode is
//!   refused with a typed reason before any mutation.
//! * [`store::SessionStore`] — session id → cache, page-denominated
//!   capacity accounting, the per-session committed stream position
//!   ([`store::SessionStore::expected_pos`] — what server-side gap
//!   detection validates against), and the pluggable
//!   [`store::EvictionPolicy`] (LRU by default; [`store::
//!   LargestFirstPolicy`] and [`store::TtlPolicy`] are the cost-aware
//!   alternatives — policies rank a store-built candidate slice that
//!   already excludes checked-out sessions, so no policy can starve
//!   under concurrent checkout). Eviction drops pages, never history:
//!   an evicted session decodes from scratch on its next step, bitwise
//!   unchanged — unless a [`store::SpillTier`] is attached, in which
//!   case eviction *spills* the victim's pages (θ rows included) to
//!   the slow tier and a later checkout *restores* them, replaying
//!   only the suffix. Checkout hands out `Arc`'d caches so a whole
//!   batch of sessions is held concurrently during the batched decode
//!   fan-out.
//!
//! * [`journal::SessionJournal`] — the fleet-wide availability layer:
//!   per-session committed token streams (plus optional θ/KV
//!   checkpoints) that a session restores from when its lane dies or
//!   drains. Restoration is bitwise replay through the same
//!   eviction-rebuild path ([`store::SessionStore::adopt`] +
//!   `checkout`'s suffix replay), pinned by
//!   `rust/tests/failover_conformance.rs`.
//!
//! The decode math lives in [`crate::attention::kernel`]
//! (`MhaKernel::decode_step`, and `MhaKernel::decode_batch` for the
//! whole-batch `sessions × layers × heads` fan-out); the serving
//! integration — session requests, position-asserted decode steps,
//! sticky session→lane affinity, lane failover/draining, the
//! `hdp serve --demo --decode` loop — lives in [`crate::coordinator`].
//! The end-to-end flow is mapped in ARCHITECTURE.md (§ Session /
//! KV-cache flow, § Failover & draining) and pinned by
//! `rust/tests/decode_conformance.rs` and
//! `rust/tests/failover_conformance.rs`.

pub mod cache;
pub mod journal;
pub mod store;

pub use cache::{HeadKv, KvCache, SessionMode, TokenRow};
pub use journal::{JournalStats, SessionJournal, SessionRestore};
pub use store::{
    EvictionCandidate, EvictionPolicy, InMemorySpillTier, KvCacheConfig, LargestFirstPolicy,
    LruPolicy, SessionStore, SpillStats, SpillTier, StoreStats, TtlPolicy,
};
