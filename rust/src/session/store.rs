//! Session-keyed store over the per-session [`KvCache`]s, with
//! explicit capacity accounting and a pluggable eviction policy.
//!
//! The store is the serving engine's view of decode state: `checkout`
//! a session before a decode step (creating or rebuilding its cache as
//! needed), run the step against the returned cache, then `commit` the
//! appended tokens — which is also where the capacity bound is
//! enforced. Checkout hands back an `Arc`'d cache, so the batched
//! decode path checks out *every* session in a popped batch up front,
//! releases the store lock for the kernel fan-out, and commits step by
//! step afterwards (the engine's validate → checkout-all → fan-out →
//! commit protocol; per-head `Mutex`es inside the caches keep the
//! concurrent multi-session work sound). Eviction is *session-granular* and drops only the heavy
//! page state: the token history survives, so an evicted session's
//! next decode step transparently **decodes from scratch** (the store
//! hands back the history to replay) and produces bitwise-identical
//! results — eviction is a performance event, never a correctness one
//! (`rust/tests/decode_conformance.rs` pins this).
//!
//! Capacity is counted in **pages** (the [`KvCache`] allocation unit)
//! across every cached session; the unit is what a real paged-KV
//! serving system budgets, and it makes the eviction trigger exact
//! rather than token-approximate. The policy decides *who* goes —
//! [`LruPolicy`] (least recently `checkout`ed) is the default; the
//! [`EvictionPolicy`] trait keeps the decision separable from the
//! bookkeeping so cost-aware policies (largest-first, TTL) can slot in
//! without touching the store.

use std::collections::HashMap;
use std::sync::Arc;

use super::cache::KvCache;

/// Geometry + budget of a session store: the per-head cache shape
/// (mirroring the engine's native model geometry, `d_v == d_head`
/// there), the pruning block edge, the page size in tokens (a multiple
/// of `block` — block-aligned growth), and the total page budget
/// across sessions (`usize::MAX` = unbounded).
#[derive(Debug, Clone, Copy)]
pub struct KvCacheConfig {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_v: usize,
    pub block: usize,
    pub page_tokens: usize,
    pub capacity_pages: usize,
}

/// Who to evict when the page budget is exceeded. The store calls
/// `touch` on every checkout, `forget` when a session's pages are
/// dropped, and `victim` (excluding the session being served) until
/// the budget holds. Implementations only rank sessions; the store
/// owns all state mutation.
pub trait EvictionPolicy: Send + std::fmt::Debug {
    /// `session` was just used — most recently used from now on.
    fn touch(&mut self, session: u64);
    /// `session`'s pages were dropped; stop tracking it.
    fn forget(&mut self, session: u64);
    /// Next victim among tracked sessions, never `keep`. `None` means
    /// nothing (else) is evictable.
    fn victim(&mut self, keep: u64) -> Option<u64>;
}

/// Least-recently-used: a logical clock stamped per touch; the victim
/// is the smallest stamp.
#[derive(Debug, Default)]
pub struct LruPolicy {
    clock: u64,
    stamp: HashMap<u64, u64>,
}

impl LruPolicy {
    pub fn new() -> Self {
        Self::default()
    }
}

impl EvictionPolicy for LruPolicy {
    fn touch(&mut self, session: u64) {
        self.clock += 1;
        self.stamp.insert(session, self.clock);
    }

    fn forget(&mut self, session: u64) {
        self.stamp.remove(&session);
    }

    fn victim(&mut self, keep: u64) -> Option<u64> {
        self.stamp
            .iter()
            .filter(|(s, _)| **s != keep)
            .min_by_key(|(_, stamp)| **stamp)
            .map(|(s, _)| *s)
    }
}

#[derive(Debug)]
struct SessionEntry {
    /// Full token history since session creation — cheap, survives
    /// eviction, and is exactly what a decode-from-scratch rebuild
    /// replays.
    history: Vec<i32>,
    /// The heavy paged state; `None` after eviction. Handed out as an
    /// [`Arc`] so a batched decode step can hold *several* sessions'
    /// caches at once (each head behind its own `Mutex`) while the
    /// store lock is released for the duration of the kernel fan-out.
    cache: Option<Arc<KvCache>>,
    /// Page count as of this session's last commit. Kept so the budget
    /// check and the eviction loop are O(1) bookkeeping instead of
    /// walking every cached session's per-head locks on the per-token
    /// hot path.
    pages: usize,
}

/// Store-lifetime counters the serving metrics surface.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub sessions_created: u64,
    pub evictions: u64,
    pub rebuilds: u64,
    /// Sessions seeded from a fleet journal (`adopt`) — lane-failover
    /// re-homes, as opposed to locally created sessions.
    pub adoptions: u64,
}

/// Session id → cache, plus the eviction machinery. See the module
/// docs for the checkout/commit protocol.
#[derive(Debug)]
pub struct SessionStore {
    cfg: KvCacheConfig,
    sessions: HashMap<u64, SessionEntry>,
    policy: Box<dyn EvictionPolicy>,
    stats: StoreStats,
    /// Σ of every entry's committed `pages` — the O(1) budget check.
    charged_pages: usize,
}

impl SessionStore {
    /// Store with the default [`LruPolicy`].
    pub fn new(cfg: KvCacheConfig) -> Self {
        Self::with_policy(cfg, Box::new(LruPolicy::new()))
    }

    pub fn with_policy(cfg: KvCacheConfig, policy: Box<dyn EvictionPolicy>) -> Self {
        assert!(cfg.capacity_pages > 0, "page budget must admit something");
        Self {
            cfg,
            sessions: HashMap::new(),
            policy,
            stats: StoreStats::default(),
            charged_pages: 0,
        }
    }

    pub fn config(&self) -> KvCacheConfig {
        self.cfg
    }

    pub fn stats(&self) -> StoreStats {
        self.stats
    }

    /// Sessions known to the store (cached or evicted).
    pub fn sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Sessions currently holding pages.
    pub fn cached_sessions(&self) -> usize {
        self.sessions.values().filter(|e| e.cache.is_some()).count()
    }

    /// Pages charged across every cached session, as of each session's
    /// last commit. The engine commits immediately after appending, so
    /// this tracks live allocation exactly at every budget-check point
    /// — in O(1), without touching other sessions' head locks.
    pub fn total_pages(&self) -> usize {
        self.charged_pages
    }

    /// Committed token history of a session (empty if unknown).
    pub fn history_len(&self, session: u64) -> usize {
        self.sessions.get(&session).map_or(0, |e| e.history.len())
    }

    /// The stream position the server expects a session's next decode
    /// step to append at — its committed context length (0 for a
    /// session the store has never seen). This is the per-session
    /// sequence number the engine's gap detection validates
    /// position-asserted decode steps against: a step claiming any
    /// other position is gapped (too high: the client ignored a
    /// rejection and kept streaming), replayed (too low) or
    /// out-of-order, and is refused before any state mutates.
    pub fn expected_pos(&self, session: u64) -> usize {
        self.history_len(session)
    }

    /// Check a session out for a decode step: touches the eviction
    /// policy, creates the session on first sight, and — when the
    /// session was evicted — allocates a fresh cache and returns the
    /// committed history the caller must replay through the decode
    /// path before appending new tokens (decode-from-scratch). The
    /// cache comes back as an [`Arc`] clone, so a batched decode can
    /// check out every session in its batch up front, drop the store
    /// lock for the kernel fan-out, and `commit` afterwards — the
    /// per-head `Mutex`es inside [`KvCache`] keep concurrent
    /// multi-session work sound without the store in the loop.
    pub fn checkout(&mut self, session: u64) -> (Arc<KvCache>, Vec<i32>) {
        if !self.sessions.contains_key(&session) {
            self.sessions.insert(
                session,
                SessionEntry { history: Vec::new(), cache: None, pages: 0 },
            );
            self.stats.sessions_created += 1;
        }
        self.policy.touch(session);
        let cfg = self.cfg;
        let entry = self.sessions.get_mut(&session).expect("just ensured");
        // A cache holding *more* tokens than the committed history can
        // only mean a step appended but never committed (an
        // interrupted serve); the prefix property is gone, so drop it
        // and rebuild from the committed stream (defensive — the
        // engine's validate-before-mutate protocol never produces it).
        if entry
            .cache
            .as_ref()
            .is_some_and(|c| c.len() > entry.history.len())
        {
            self.charged_pages -= entry.pages;
            entry.pages = 0;
            entry.cache = None;
        }
        if entry.cache.is_none() {
            entry.cache = Some(Arc::new(KvCache::new(
                cfg.n_layers,
                cfg.n_heads,
                cfg.d_head,
                cfg.d_v,
                cfg.block,
                cfg.page_tokens,
            )));
        }
        let cache = entry.cache.as_ref().expect("just ensured");
        // Replay whatever committed history the cache is missing.
        // Covers the full spectrum with one rule: a warm cache replays
        // nothing, an evicted session replays everything, and a
        // checkpoint-seeded cache (see `adopt`) replays only the
        // suffix past the checkpoint — all bitwise identical, because
        // incremental decode equals full recompute at every step.
        let cached = cache.len();
        let replay = if cached < entry.history.len() {
            self.stats.rebuilds += 1;
            entry.history[cached..].to_vec()
        } else {
            Vec::new()
        };
        (Arc::clone(cache), replay)
    }

    /// Seed a re-homed session from the fleet journal: install its
    /// committed token stream and, when the journal carries a θ/KV
    /// checkpoint no longer than the stream, a deep copy of the
    /// checkpointed cache so the next `checkout` replays only the
    /// suffix past it. A session whose local history is already at
    /// least as long is untouched (the journal can never be *behind*
    /// a correct lane — commits reach it before responses exist); a
    /// shorter local prefix keeps its cache (append-only streams make
    /// any prefix consistent) and just extends the history.
    pub fn adopt(
        &mut self,
        session: u64,
        tokens: &[i32],
        checkpoint: Option<(usize, &KvCache)>,
    ) {
        let entry = self.sessions.entry(session).or_insert_with(|| {
            SessionEntry { history: Vec::new(), cache: None, pages: 0 }
        });
        if entry.history.len() >= tokens.len() {
            return;
        }
        debug_assert_eq!(
            &tokens[..entry.history.len()],
            &entry.history[..],
            "journal must extend the local stream, never contradict it"
        );
        entry.history = tokens.to_vec();
        if entry.cache.is_none() {
            if let Some((at, snap)) = checkpoint {
                if at <= tokens.len() && at == snap.len() {
                    let cache = Arc::new(snap.snapshot());
                    self.charged_pages += cache.pages();
                    entry.pages = cache.pages();
                    entry.cache = Some(cache);
                }
            }
        }
        self.stats.adoptions += 1;
        self.policy.touch(session);
        // A checkpoint's pages count against the budget like any other
        // resident state; shed colder sessions if it overflowed.
        self.enforce_budget(session);
    }

    fn enforce_budget(&mut self, keep: u64) {
        while self.charged_pages > self.cfg.capacity_pages {
            let victim = match self.policy.victim(keep) {
                Some(v) => v,
                None => break, // nothing (else) evictable: let it run
            };
            self.policy.forget(victim);
            if let Some(e) = self.sessions.get_mut(&victim) {
                if e.cache.take().is_some() {
                    self.charged_pages -= e.pages;
                    e.pages = 0;
                    self.stats.evictions += 1;
                }
            }
        }
    }

    /// Record tokens appended to a checked-out session and enforce the
    /// page budget, evicting least-recently-used *other* sessions until
    /// it holds (the active session is never evicted under itself —
    /// a single oversized session may exceed the budget alone).
    pub fn commit(&mut self, session: u64, appended: &[i32]) {
        if let Some(e) = self.sessions.get_mut(&session) {
            e.history.extend_from_slice(appended);
            // Re-charge only this session's pages (its heads are idle
            // now); every other session keeps its committed count.
            let now = e.cache.as_ref().map_or(0, |c| c.pages());
            self.charged_pages = self.charged_pages - e.pages + now;
            e.pages = now;
        }
        self.enforce_budget(session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::cache::TokenRow;

    fn cfg(capacity_pages: usize) -> KvCacheConfig {
        KvCacheConfig {
            n_layers: 1,
            n_heads: 1,
            d_head: 4,
            d_v: 4,
            block: 2,
            page_tokens: 2,
            capacity_pages,
        }
    }

    fn row() -> TokenRow {
        TokenRow {
            iq: vec![1.0; 4],
            fq: vec![0.0; 4],
            ik: vec![1.0; 4],
            fk: vec![0.0; 4],
            v: vec![1.0; 4],
        }
    }

    /// Append `n` tokens to every head of `session` and commit them.
    fn grow(store: &mut SessionStore, session: u64, n: usize) {
        let (cache, replay) = store.checkout(session);
        assert!(replay.is_empty(), "warm session needs no replay");
        for _ in 0..n {
            cache.head(0, 0).lock().unwrap().append(&row());
        }
        store.commit(session, &vec![7i32; n]);
    }

    #[test]
    fn lru_policy_orders_by_recency() {
        let mut p = LruPolicy::new();
        p.touch(1);
        p.touch(2);
        p.touch(3);
        p.touch(1); // 2 is now the oldest
        assert_eq!(p.victim(99), Some(2));
        assert_eq!(p.victim(2), Some(3), "excluded session skipped");
        p.forget(2);
        assert_eq!(p.victim(99), Some(3));
        p.forget(3);
        p.forget(1);
        assert_eq!(p.victim(99), None, "nothing tracked");
    }

    #[test]
    fn capacity_evicts_lru_session_and_keeps_history() {
        // 2-token pages, budget 4 pages: two 4-token sessions fill it;
        // a third session evicts the least recently used (session 1).
        let mut store = SessionStore::new(cfg(4));
        grow(&mut store, 1, 4);
        grow(&mut store, 2, 4);
        assert_eq!(store.total_pages(), 4);
        assert_eq!(store.cached_sessions(), 2);
        grow(&mut store, 3, 2);
        assert_eq!(store.stats().evictions, 1);
        assert_eq!(store.cached_sessions(), 2, "one session dropped pages");
        assert!(store.total_pages() <= 4);
        // Session 1 lost its pages but not its history...
        assert_eq!(store.history_len(1), 4);
        // ...and checking it out again rebuilds: fresh cache + replay.
        let (cache, replay) = store.checkout(1);
        assert_eq!(replay, vec![7i32; 4], "full history handed back");
        assert_eq!(cache.len(), 0, "fresh cache, caller replays");
        assert_eq!(store.stats().rebuilds, 1);
    }

    #[test]
    fn active_session_never_self_evicts() {
        // One session alone may exceed the budget: nothing else to
        // evict, so the store lets it run rather than thrash.
        let mut store = SessionStore::new(cfg(2));
        grow(&mut store, 5, 10); // 5 pages > budget 2
        assert_eq!(store.stats().evictions, 0);
        assert_eq!(store.total_pages(), 5);
        // A second session now triggers eviction of the first.
        grow(&mut store, 6, 2);
        assert_eq!(store.stats().evictions, 1);
        assert_eq!(store.total_pages(), 1);
    }

    #[test]
    fn touch_order_protects_hot_sessions() {
        let mut store = SessionStore::new(cfg(4));
        grow(&mut store, 1, 4);
        grow(&mut store, 2, 4);
        // Re-touch session 1: session 2 becomes the LRU victim.
        let _ = store.checkout(1);
        grow(&mut store, 3, 2);
        assert_eq!(store.history_len(2), 4);
        let (_, replay) = store.checkout(2);
        assert_eq!(replay.len(), 4, "evicted session 2 must replay");
        let (_, no_replay) = store.checkout(1);
        assert!(no_replay.is_empty(), "session 1 stayed cached");
    }

    #[test]
    fn charged_pages_track_live_allocation() {
        // The O(1) accounting must agree with a live walk of every
        // cached session after each commit, across growth, eviction
        // and rebuild.
        let mut store = SessionStore::new(cfg(6));
        for (s, n) in [(1u64, 3usize), (2, 5), (1, 2), (3, 4), (1, 1)] {
            grow_any(&mut store, s, n);
            let live: usize = store
                .sessions
                .values()
                .filter_map(|e| e.cache.as_ref())
                .map(|c| c.pages())
                .sum();
            assert_eq!(store.total_pages(), live, "after session {s} += {n}");
        }
    }

    /// Like `grow`, but tolerates the session having been evicted
    /// (replays its history first, as the engine would).
    fn grow_any(store: &mut SessionStore, session: u64, n: usize) {
        let (cache, replay) = store.checkout(session);
        for _ in 0..replay.len() + n {
            cache.head(0, 0).lock().unwrap().append(&row());
        }
        store.commit(session, &vec![7i32; n]);
    }

    #[test]
    fn multiple_sessions_check_out_concurrently() {
        // The batched-decode shape: every session in a batch checked
        // out up front (Arc handles), worked concurrently through the
        // per-head locks, then committed — with the store free in
        // between.
        let mut store = SessionStore::new(cfg(usize::MAX));
        let (a, ra) = store.checkout(1);
        let (b, rb) = store.checkout(2);
        assert!(ra.is_empty() && rb.is_empty());
        std::thread::scope(|s| {
            for cache in [&a, &b] {
                s.spawn(move || {
                    for _ in 0..3 {
                        cache.head(0, 0).lock().unwrap().append(&row());
                    }
                });
            }
        });
        store.commit(1, &[7, 7, 7]);
        store.commit(2, &[8, 8, 8]);
        assert_eq!(store.history_len(1), 3);
        assert_eq!(store.history_len(2), 3);
        assert_eq!(store.total_pages(), 4, "2 pages per 3-token session");
    }

    #[test]
    fn expected_pos_tracks_committed_stream_position() {
        let mut store = SessionStore::new(cfg(4));
        assert_eq!(store.expected_pos(1), 0, "unknown session starts at 0");
        grow(&mut store, 1, 3);
        assert_eq!(store.expected_pos(1), 3);
        grow(&mut store, 1, 1);
        assert_eq!(store.expected_pos(1), 4);
        // Eviction drops pages, never the stream position: the session
        // still appends at its committed length.
        grow(&mut store, 2, 6); // 3 pages: evicts session 1 (budget 4)
        assert_eq!(store.stats().evictions, 1);
        assert_eq!(store.expected_pos(1), 4, "position survives eviction");
    }

    #[test]
    fn adopt_seeds_history_and_suffix_replays_past_checkpoint() {
        // A re-homed session with a checkpoint at 4 of 6 tokens must
        // check out replaying only the 2-token suffix.
        let c = cfg(usize::MAX);
        let mut donor = SessionStore::new(c);
        grow(&mut donor, 9, 4);
        let (snap_src, _) = donor.checkout(9);
        let snap = snap_src.snapshot();

        let mut store = SessionStore::new(c);
        let full: Vec<i32> = vec![7; 6];
        store.adopt(9, &full, Some((4, &snap)));
        assert_eq!(store.stats().adoptions, 1);
        assert_eq!(store.expected_pos(9), 6);
        let (cache, replay) = store.checkout(9);
        assert_eq!(cache.len(), 4, "checkpoint pages installed");
        assert_eq!(replay, vec![7i32; 2], "only the suffix replays");
        assert_eq!(store.stats().rebuilds, 1);
        assert_eq!(store.total_pages(), cache.pages());
    }

    #[test]
    fn adopt_without_checkpoint_replays_everything() {
        let mut store = SessionStore::new(cfg(usize::MAX));
        store.adopt(3, &[1, 2, 3, 4, 5], None);
        let (cache, replay) = store.checkout(3);
        assert_eq!(cache.len(), 0);
        assert_eq!(replay, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn adopt_is_idempotent_and_never_rewinds() {
        let mut store = SessionStore::new(cfg(usize::MAX));
        grow(&mut store, 1, 4);
        // A journal at or behind the local stream is a no-op: the
        // local lane already owns at least this much committed state.
        store.adopt(1, &[7, 7, 7], None);
        store.adopt(1, &[7, 7, 7, 7], None);
        assert_eq!(store.stats().adoptions, 0);
        assert_eq!(store.expected_pos(1), 4);
        let (_, replay) = store.checkout(1);
        assert!(replay.is_empty(), "warm cache untouched by adopt");
        // A longer journal extends the history; the warm cache stays
        // (it is a consistent prefix) and only the gap replays.
        store.adopt(1, &[7, 7, 7, 7, 9, 9], None);
        assert_eq!(store.stats().adoptions, 1);
        let (cache, replay) = store.checkout(1);
        assert_eq!(cache.len(), 4);
        assert_eq!(replay, vec![9, 9]);
    }

    #[test]
    fn adopted_checkpoint_pages_count_against_budget() {
        let c = cfg(usize::MAX);
        let mut donor = SessionStore::new(c);
        grow(&mut donor, 1, 6);
        let (src, _) = donor.checkout(1);
        let snap = src.snapshot(); // 3 pages at 2 tokens/page

        let mut store = SessionStore::new(cfg(4));
        grow(&mut store, 2, 4); // 2 pages resident
        store.adopt(1, &vec![7i32; 6], Some((6, &snap)));
        // 3 + 2 = 5 pages > budget 4: the colder session 2 is evicted.
        assert_eq!(store.stats().evictions, 1);
        assert!(store.total_pages() <= 4);
        let (_, replay) = store.checkout(1);
        assert!(replay.is_empty(), "adopted session kept its checkpoint");
    }

    #[test]
    fn overlong_cache_is_dropped_and_rebuilt() {
        // An appended-but-never-committed cache (interrupted serve)
        // must not survive checkout: the store rebuilds from the
        // committed history.
        let mut store = SessionStore::new(cfg(usize::MAX));
        grow(&mut store, 1, 2);
        let (cache, _) = store.checkout(1);
        cache.head(0, 0).lock().unwrap().append(&row()); // no commit
        let (fresh, replay) = store.checkout(1);
        assert_eq!(fresh.len(), 0, "tainted cache dropped");
        assert_eq!(replay, vec![7i32; 2], "committed stream replays");
    }

    #[test]
    fn stats_track_creation() {
        let mut store = SessionStore::new(cfg(usize::MAX));
        grow(&mut store, 1, 1);
        grow(&mut store, 1, 1);
        grow(&mut store, 2, 1);
        let s = store.stats();
        assert_eq!(s.sessions_created, 2);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.rebuilds, 0);
        assert_eq!(store.sessions(), 2);
        assert_eq!(store.history_len(1), 2);
    }
}
