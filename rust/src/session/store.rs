//! Session-keyed store over the per-session [`KvCache`]s, with
//! explicit capacity accounting, a pluggable eviction policy, and an
//! optional spill tier — the first rung of a production KV memory
//! hierarchy.
//!
//! The store is the serving engine's view of decode state: `checkout`
//! a session before a decode step (creating or rebuilding its cache as
//! needed), run the step against the returned cache, then `commit` the
//! appended tokens — which is also where the capacity bound is
//! enforced. Checkout hands back an `Arc`'d cache, so the batched
//! decode path checks out *every* session in a popped batch up front,
//! releases the store lock for the kernel fan-out, and commits step by
//! step afterwards (the engine's validate → checkout-all → fan-out →
//! commit protocol; per-head `Mutex`es inside the caches keep the
//! concurrent multi-session work sound). Eviction is *session-granular* and drops only the heavy
//! page state: the token history survives, so an evicted session's
//! next decode step transparently **decodes from scratch** (the store
//! hands back the history to replay) and produces bitwise-identical
//! results — eviction is a performance event, never a correctness one
//! (`rust/tests/decode_conformance.rs` pins this).
//!
//! Capacity is counted in **pages** (the [`KvCache`] allocation unit)
//! across every cached session; the unit is what a real paged-KV
//! serving system budgets, and it makes the eviction trigger exact
//! rather than token-approximate.
//!
//! **Who goes** is the policy's call, but on the store's terms: each
//! round of budget enforcement the store builds a slice of
//! [`EvictionCandidate`]s — every session *except* the one being
//! served, sessions whose cache is checked out elsewhere (`Arc` held
//! outside the store), and sessions with no pages to free — and the
//! [`EvictionPolicy`] only *ranks* that slice. Policies therefore
//! cannot starve the budget loop or evict a cache that a concurrent
//! batch is decoding into, no matter how they order candidates.
//! [`LruPolicy`] (least recently `checkout`ed) is the default;
//! [`LargestFirstPolicy`] (most pages freed per eviction) and
//! [`TtlPolicy`] (idle-expiry with an LRU fallback) are the cost-aware
//! alternatives.
//!
//! **Where the pages go** is the [`SpillTier`]'s call: with a tier
//! attached ([`SessionStore::attach_spill_tier`]), eviction *spills*
//! the victim's full snapshot — KV pages plus θ state, row-only in
//! causal mode — to the slow tier instead of discarding it, and the
//! session's next `checkout` *restores* the snapshot and replays only
//! whatever suffix committed after the spill. Restore-from-tier and
//! decode-from-scratch are bitwise interchangeable (the snapshot is a
//! verbatim deep copy of state that is itself pinned bitwise against
//! full recompute), so the tier, like eviction, is purely a
//! performance event. Spilled pages are *not* charged against the
//! budget; [`SpillStats`] counts spills/restores and nominal bytes
//! moved for the serving metrics.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use super::cache::{KvCache, SessionMode};
use crate::policy::PolicyId;

/// Geometry + budget of a session store: the per-head cache shape
/// (mirroring the engine's native model geometry, `d_v == d_head`
/// there), the pruning block edge, the page size in tokens (a multiple
/// of `block` — block-aligned growth), and the total page budget
/// across sessions (`usize::MAX` = unbounded).
#[derive(Debug, Clone, Copy)]
pub struct KvCacheConfig {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_v: usize,
    pub block: usize,
    pub page_tokens: usize,
    pub capacity_pages: usize,
}

impl KvCacheConfig {
    /// Nominal payload of one page: `page_tokens` rows of iq/ik/fk
    /// (`d_head` lanes each) and v (`d_v` lanes) on the f32 grid. Used
    /// to denominate spill/restore traffic in bytes for the metrics —
    /// a fixed per-page figure, so byte counters stay exact multiples
    /// of page moves.
    pub fn page_bytes(&self) -> usize {
        self.page_tokens * (3 * self.d_head + self.d_v) * std::mem::size_of::<f32>()
    }
}

/// One evictable session as the store presents it to the policy: the
/// stable id, the pages an eviction would free, and the logical-clock
/// stamp of its last `checkout`/`adopt` (the store's clock ticks once
/// per touch; larger = more recent). The store pre-filters the slice —
/// the session being served, `Arc`-held (checked-out) caches, and
/// pageless sessions never appear — so any ranking over it is safe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvictionCandidate {
    pub session: u64,
    pub pages: usize,
    pub last_touch: u64,
}

/// Ranks eviction candidates when the page budget is exceeded. The
/// store owns all state and bookkeeping: it builds the candidate
/// slice (already excluding the served session, checked-out caches,
/// and pageless entries), passes its logical clock as `now`, and
/// evicts whichever candidate the policy names — one per round, until
/// the budget holds or the slice is empty. Policies are pure ranking
/// functions over the slice, which is what makes them starvation-free
/// under concurrent checkout by construction.
pub trait EvictionPolicy: Send + std::fmt::Debug {
    /// The victim among `candidates`, or `None` to decline (the store
    /// stops evicting this round). `now` is the store's logical clock
    /// — the same units as [`EvictionCandidate::last_touch`].
    fn select(&self, now: u64, candidates: &[EvictionCandidate]) -> Option<u64>;
}

/// Least-recently-used: the candidate with the smallest touch stamp.
#[derive(Debug, Clone, Copy, Default)]
pub struct LruPolicy;

impl LruPolicy {
    pub fn new() -> Self {
        Self
    }
}

impl EvictionPolicy for LruPolicy {
    fn select(&self, _now: u64, candidates: &[EvictionCandidate]) -> Option<u64> {
        candidates.iter().min_by_key(|c| c.last_touch).map(|c| c.session)
    }
}

/// Cost-aware largest-first: evict the candidate freeing the most
/// pages, so the budget closes in the fewest evictions (each one may
/// cost a future rebuild or restore). Ties break toward the *least*
/// recently used, i.e. LRU among equals.
#[derive(Debug, Clone, Copy, Default)]
pub struct LargestFirstPolicy;

impl LargestFirstPolicy {
    pub fn new() -> Self {
        Self
    }
}

impl EvictionPolicy for LargestFirstPolicy {
    fn select(&self, _now: u64, candidates: &[EvictionCandidate]) -> Option<u64> {
        candidates
            .iter()
            // Max by pages; on equal pages the *older* stamp wins the
            // comparison (reversed order), so `max_by` lands on it.
            .max_by(|a, b| {
                a.pages
                    .cmp(&b.pages)
                    .then(b.last_touch.cmp(&a.last_touch))
            })
            .map(|c| c.session)
    }
}

/// Time-to-live in logical-clock ticks (one tick per store touch, so
/// deterministic and simulation-friendly): a candidate is *expired*
/// once it has sat idle for more than `ttl` ticks, and the oldest
/// expired candidate goes first. When nothing has expired the policy
/// **falls back to LRU** rather than declining — the budget is a hard
/// bound and must still close; TTL only changes who pays, preferring
/// provably idle sessions when they exist.
#[derive(Debug, Clone, Copy)]
pub struct TtlPolicy {
    ttl: u64,
}

impl TtlPolicy {
    pub fn new(ttl: u64) -> Self {
        assert!(ttl > 0, "zero TTL is plain LRU; use LruPolicy");
        Self { ttl }
    }
}

impl EvictionPolicy for TtlPolicy {
    fn select(&self, now: u64, candidates: &[EvictionCandidate]) -> Option<u64> {
        candidates
            .iter()
            .filter(|c| now.saturating_sub(c.last_touch) > self.ttl)
            .min_by_key(|c| c.last_touch)
            .or_else(|| candidates.iter().min_by_key(|c| c.last_touch))
            .map(|c| c.session)
    }
}

/// A slower, larger memory tier that evicted sessions' page state can
/// move to instead of being discarded. Implementations store verbatim
/// [`KvCache`] snapshots keyed by session — KV pages *and* θ state
/// (row-only in causal mode), so a restore resumes incremental decode
/// exactly where the spill left it, bitwise. The store drives both
/// directions: eviction under page pressure calls `spill`, the
/// session's next checkout calls `restore` (which removes the
/// snapshot — the tier never holds a stale copy of a resident
/// session).
pub trait SpillTier: Send + std::fmt::Debug {
    /// Persist `snapshot` for `session`, replacing any earlier spill.
    fn spill(&mut self, session: u64, snapshot: KvCache);
    /// Remove and return the spilled snapshot, if one exists.
    fn restore(&mut self, session: u64) -> Option<KvCache>;
    /// Sessions currently resident in the tier.
    fn spilled(&self) -> usize;
}

/// Default slow tier: an in-process map. Stands in for host RAM
/// behind an accelerator's HBM — the latency gap is real in
/// production but the *protocol* (what moves, when, and the bitwise
/// restore guarantee) is identical, which is what the conformance
/// suites pin.
#[derive(Debug, Default)]
pub struct InMemorySpillTier {
    slots: HashMap<u64, KvCache>,
}

impl InMemorySpillTier {
    pub fn new() -> Self {
        Self::default()
    }
}

impl SpillTier for InMemorySpillTier {
    fn spill(&mut self, session: u64, snapshot: KvCache) {
        self.slots.insert(session, snapshot);
    }

    fn restore(&mut self, session: u64) -> Option<KvCache> {
        self.slots.remove(&session)
    }

    fn spilled(&self) -> usize {
        self.slots.len()
    }
}

#[derive(Debug)]
struct SessionEntry {
    /// Full token history since session creation — cheap, survives
    /// eviction, and is exactly what a decode-from-scratch rebuild
    /// replays.
    history: Vec<i32>,
    /// The heavy paged state; `None` after eviction. Handed out as an
    /// [`Arc`] so a batched decode step can hold *several* sessions'
    /// caches at once (each head behind its own `Mutex`) while the
    /// store lock is released for the duration of the kernel fan-out.
    cache: Option<Arc<KvCache>>,
    /// Page count as of this session's last commit. Kept so the budget
    /// check and the eviction loop are O(1) bookkeeping instead of
    /// walking every cached session's per-head locks on the per-token
    /// hot path.
    pages: usize,
    /// Logical-clock stamp of the last `checkout`/`adopt` — the
    /// recency signal every [`EvictionPolicy`] ranks on.
    last_touch: u64,
    /// How this session attends, fixed at first sight. Cache
    /// allocations (fresh or rebuilt) always use it, and the engine
    /// refuses any later step naming a different mode before touching
    /// state.
    mode: SessionMode,
    /// The pruning-policy class the session decodes at, fixed when the
    /// engine first serves it (`None` until then — checkout alone does
    /// not decide a class). Like `mode`, the engine refuses any later
    /// step claiming a different class before touching state.
    policy: Option<PolicyId>,
}

/// Store-lifetime counters the serving metrics surface.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub sessions_created: u64,
    pub evictions: u64,
    pub rebuilds: u64,
    /// Sessions seeded from a fleet journal (`adopt`) — lane-failover
    /// re-homes, as opposed to locally created sessions.
    pub adoptions: u64,
}

/// Spill-tier traffic counters: how many sessions moved each way and
/// the nominal bytes (pages × [`KvCacheConfig::page_bytes`]) they
/// carried. Zero whenever no tier is attached.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpillStats {
    pub spills: u64,
    pub restores: u64,
    pub bytes_spilled: u64,
    pub bytes_restored: u64,
}

/// Session id → cache, plus the eviction machinery. See the module
/// docs for the checkout/commit protocol.
#[derive(Debug)]
pub struct SessionStore {
    cfg: KvCacheConfig,
    sessions: HashMap<u64, SessionEntry>,
    policy: Box<dyn EvictionPolicy>,
    spill: Option<Box<dyn SpillTier>>,
    stats: StoreStats,
    spill_stats: SpillStats,
    /// Σ of every entry's committed `pages` — the O(1) budget check.
    /// Spilled sessions charge nothing here.
    charged_pages: usize,
    /// Logical clock: one tick per `checkout`/`adopt`. Denominates
    /// [`EvictionCandidate::last_touch`] and [`TtlPolicy`] idle time.
    clock: u64,
    /// Sessions with a chunked prefill in flight: opened by the
    /// continuous scheduler's slicer (and by every interior-chunk
    /// commit, so an adopting lane re-learns the state from the
    /// readmitted chunks themselves), closed when the final chunk
    /// commits or the stream is cancelled. While a session is here, a
    /// decode step claiming a position *past* the committed length is
    /// refused with the retryable `PrefillIncomplete` instead of the
    /// fatal `StreamGap` — the missing positions are in flight, not
    /// lost. Deliberately a side table, not entry state: it must be
    /// settable before the session's first commit creates an entry,
    /// and eviction/spill (which drop pages, never history) must not
    /// disturb it.
    prefill_open: HashSet<u64>,
}

impl SessionStore {
    /// Store with the default [`LruPolicy`] and no spill tier.
    pub fn new(cfg: KvCacheConfig) -> Self {
        Self::with_policy(cfg, Box::new(LruPolicy::new()))
    }

    pub fn with_policy(cfg: KvCacheConfig, policy: Box<dyn EvictionPolicy>) -> Self {
        assert!(cfg.capacity_pages > 0, "page budget must admit something");
        Self {
            cfg,
            sessions: HashMap::new(),
            policy,
            spill: None,
            stats: StoreStats::default(),
            spill_stats: SpillStats::default(),
            charged_pages: 0,
            clock: 0,
            prefill_open: HashSet::new(),
        }
    }

    /// Swap the eviction policy. Policies are pure rankings over
    /// store-built candidate slices, so swapping mid-life is safe —
    /// the next budget round simply ranks differently.
    pub fn set_policy(&mut self, policy: Box<dyn EvictionPolicy>) {
        self.policy = policy;
    }

    /// Attach (or replace) the slow tier evictions spill to. Sessions
    /// already spilled to a previous tier are lost to the store —
    /// their next checkout falls back to decode-from-scratch, which
    /// is bitwise identical anyway.
    pub fn attach_spill_tier(&mut self, tier: Box<dyn SpillTier>) {
        self.spill = Some(tier);
    }

    pub fn config(&self) -> KvCacheConfig {
        self.cfg
    }

    pub fn stats(&self) -> StoreStats {
        self.stats
    }

    pub fn spill_stats(&self) -> SpillStats {
        self.spill_stats
    }

    /// Sessions currently resident in the attached spill tier (0
    /// without one).
    pub fn spilled_sessions(&self) -> usize {
        self.spill.as_ref().map_or(0, |t| t.spilled())
    }

    /// Sessions known to the store (cached or evicted).
    pub fn sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Sessions currently holding pages.
    pub fn cached_sessions(&self) -> usize {
        self.sessions.values().filter(|e| e.cache.is_some()).count()
    }

    /// Pages charged across every cached session, as of each session's
    /// last commit. The engine commits immediately after appending, so
    /// this tracks live allocation exactly at every budget-check point
    /// — in O(1), without touching other sessions' head locks.
    pub fn total_pages(&self) -> usize {
        self.charged_pages
    }

    /// Committed token history of a session (empty if unknown).
    pub fn history_len(&self, session: u64) -> usize {
        self.sessions.get(&session).map_or(0, |e| e.history.len())
    }

    /// The attention mode a session was opened with (`None` for a
    /// session the store has never seen). This is what the engine's
    /// validate-before-mutate step checks a decode request's claimed
    /// mode against: a mismatch is refused with a typed reason before
    /// any state — cache, history, journal — is touched.
    pub fn mode_of(&self, session: u64) -> Option<SessionMode> {
        self.sessions.get(&session).map(|e| e.mode)
    }

    /// The pruning-policy class a session is pinned to (`None` for a
    /// session the store has never seen *or* one checked out but not
    /// yet served — the engine records the class via [`Self::note_policy`]
    /// on first serve). The engine's validate-before-mutate step checks
    /// a decode request's claimed class against this, exactly like
    /// [`Self::mode_of`] for modes.
    pub fn policy_of(&self, session: u64) -> Option<PolicyId> {
        self.sessions.get(&session).and_then(|e| e.policy)
    }

    /// Pin a session's pruning-policy class on first serve. A no-op
    /// when the class is already recorded — the engine's validation
    /// guarantees agreement, which the debug assert re-checks — and for
    /// sessions the store has never seen.
    pub fn note_policy(&mut self, session: u64, policy: PolicyId) {
        if let Some(e) = self.sessions.get_mut(&session) {
            match e.policy {
                None => e.policy = Some(policy),
                Some(p) => debug_assert_eq!(
                    p, policy,
                    "policy mismatches are refused by the engine before checkout"
                ),
            }
        }
    }

    /// The stream position the server expects a session's next decode
    /// step to append at — its committed context length (0 for a
    /// session the store has never seen). This is the per-session
    /// sequence number the engine's gap detection validates
    /// position-asserted decode steps against: a step claiming any
    /// other position is gapped (too high: the client ignored a
    /// rejection and kept streaming), replayed (too low) or
    /// out-of-order, and is refused before any state mutates.
    pub fn expected_pos(&self, session: u64) -> usize {
        self.history_len(session)
    }

    /// Mark a session's chunked prefill in flight (`open = true`) or
    /// complete/cancelled (`open = false`). The continuous scheduler
    /// opens it when it slices an admitted prefill (and every
    /// interior-chunk commit re-opens it, so an adopting lane
    /// re-learns the state from readmitted chunks after a failover);
    /// the final chunk's commit — or a refusal that cancels the stream
    /// — closes it. Idempotent both ways.
    pub fn note_prefill(&mut self, session: u64, open: bool) {
        if open {
            self.prefill_open.insert(session);
        } else {
            self.prefill_open.remove(&session);
        }
    }

    /// Whether a chunked prefill is currently streaming into this
    /// session — i.e. positions past [`Self::expected_pos`] are *in
    /// flight*, not lost. Gap detection consults this to answer a
    /// too-early decode step with the retryable
    /// `RejectReason::PrefillIncomplete` (retry once the stream
    /// commits) instead of the fatal `StreamGap`.
    pub fn prefill_open(&self, session: u64) -> bool {
        self.prefill_open.contains(&session)
    }

    /// [`Self::checkout_mode`] with the session's recorded mode (or
    /// the default for a first sight) — the path for callers that
    /// already validated the request mode, and for rebuild-only flows
    /// like failover replay.
    pub fn checkout(&mut self, session: u64) -> (Arc<KvCache>, Vec<i32>) {
        let mode = self.mode_of(session).unwrap_or_default();
        self.checkout_mode(session, mode)
    }

    /// Check a session out for a decode step: touches the recency
    /// clock, creates the session on first sight (fixing `mode` for
    /// its lifetime), and — when the session was evicted — restores
    /// its snapshot from the spill tier if one is resident, else
    /// allocates a fresh cache. Either way the caller gets back the
    /// committed history the cache is missing and must replay through
    /// the decode path before appending new tokens (empty for a warm
    /// or fully-restored cache; everything for decode-from-scratch;
    /// the suffix past a checkpoint or spill point otherwise — all
    /// bitwise identical, because incremental decode equals full
    /// recompute at every step and spill snapshots are verbatim). The
    /// cache comes back as an [`Arc`] clone, so a batched decode can
    /// check out every session in its batch up front, drop the store
    /// lock for the kernel fan-out, and `commit` afterwards — the
    /// per-head `Mutex`es inside [`KvCache`] keep concurrent
    /// multi-session work sound without the store in the loop, and an
    /// outstanding `Arc` also shields the session from eviction.
    pub fn checkout_mode(
        &mut self,
        session: u64,
        mode: SessionMode,
    ) -> (Arc<KvCache>, Vec<i32>) {
        if !self.sessions.contains_key(&session) {
            self.sessions.insert(
                session,
                SessionEntry {
                    history: Vec::new(),
                    cache: None,
                    pages: 0,
                    last_touch: 0,
                    mode,
                    policy: None,
                },
            );
            self.stats.sessions_created += 1;
        }
        self.clock += 1;
        let cfg = self.cfg;
        let page_bytes = cfg.page_bytes();
        let now = self.clock;
        let entry = self.sessions.get_mut(&session).expect("just ensured");
        entry.last_touch = now;
        debug_assert_eq!(
            entry.mode, mode,
            "mode mismatches are refused by the engine before checkout"
        );
        // A cache holding *more* tokens than the committed history can
        // only mean a step appended but never committed (an
        // interrupted serve); the prefix property is gone, so drop it
        // and rebuild from the committed stream (defensive — the
        // engine's validate-before-mutate protocol never produces it).
        if entry
            .cache
            .as_ref()
            .is_some_and(|c| c.len() > entry.history.len())
        {
            self.charged_pages -= entry.pages;
            entry.pages = 0;
            entry.cache = None;
        }
        if entry.cache.is_none() {
            // Evicted: prefer restoring the spilled snapshot over
            // decoding from scratch. The snapshot re-charges its pages
            // (commit re-enforces the budget); a snapshot that somehow
            // outran the committed history is discarded — the prefix
            // property is the correctness line.
            if let Some(tier) = self.spill.as_mut() {
                if let Some(snap) = tier.restore(session) {
                    if snap.len() <= entry.history.len() && snap.mode() == entry.mode {
                        self.spill_stats.restores += 1;
                        self.spill_stats.bytes_restored +=
                            (snap.pages() * page_bytes) as u64;
                        let cache = Arc::new(snap);
                        self.charged_pages += cache.pages();
                        entry.pages = cache.pages();
                        entry.cache = Some(cache);
                    }
                }
            }
        }
        if entry.cache.is_none() {
            entry.cache = Some(Arc::new(KvCache::with_mode(
                cfg.n_layers,
                cfg.n_heads,
                cfg.d_head,
                cfg.d_v,
                cfg.block,
                cfg.page_tokens,
                entry.mode,
            )));
        }
        let cache = entry.cache.as_ref().expect("just ensured");
        // Replay whatever committed history the cache is missing.
        let cached = cache.len();
        let replay = if cached < entry.history.len() {
            self.stats.rebuilds += 1;
            entry.history[cached..].to_vec()
        } else {
            Vec::new()
        };
        (Arc::clone(cache), replay)
    }

    /// Seed a re-homed session from the fleet journal: install its
    /// committed token stream and, when the journal carries a θ/KV
    /// checkpoint no longer than the stream, a deep copy of the
    /// checkpointed cache so the next `checkout` replays only the
    /// suffix past it. A session whose local history is already at
    /// least as long is untouched (the journal can never be *behind*
    /// a correct lane — commits reach it before responses exist); a
    /// shorter local prefix keeps its cache (append-only streams make
    /// any prefix consistent) and just extends the history. `mode` and
    /// `policy` are the journaled session mode and pruning class —
    /// they fix both for a session the store has never seen, exactly
    /// like a first serve.
    pub fn adopt(
        &mut self,
        session: u64,
        mode: SessionMode,
        policy: PolicyId,
        tokens: &[i32],
        checkpoint: Option<(usize, &KvCache)>,
    ) {
        self.clock += 1;
        let now = self.clock;
        let entry = self.sessions.entry(session).or_insert_with(|| SessionEntry {
            history: Vec::new(),
            cache: None,
            pages: 0,
            last_touch: 0,
            mode,
            policy: Some(policy),
        });
        debug_assert_eq!(
            entry.mode, mode,
            "journal and store must agree on a session's mode"
        );
        match entry.policy {
            None => entry.policy = Some(policy),
            Some(p) => debug_assert_eq!(
                p, policy,
                "journal and store must agree on a session's pruning class"
            ),
        }
        if entry.history.len() >= tokens.len() {
            return;
        }
        debug_assert_eq!(
            &tokens[..entry.history.len()],
            &entry.history[..],
            "journal must extend the local stream, never contradict it"
        );
        entry.history = tokens.to_vec();
        entry.last_touch = now;
        if entry.cache.is_none() {
            if let Some((at, snap)) = checkpoint {
                if at <= tokens.len() && at == snap.len() {
                    let cache = Arc::new(snap.snapshot());
                    self.charged_pages += cache.pages();
                    entry.pages = cache.pages();
                    entry.cache = Some(cache);
                }
            }
        }
        self.stats.adoptions += 1;
        // A checkpoint's pages count against the budget like any other
        // resident state; shed colder sessions if it overflowed.
        self.enforce_budget(session);
    }

    fn enforce_budget(&mut self, keep: u64) {
        while self.charged_pages > self.cfg.capacity_pages {
            // Rebuilt every round: an eviction changes the slice, and
            // `Arc::strong_count == 1` (only the store's handle) is
            // what guarantees no checked-out cache is ever a
            // candidate — the engine holds its `Arc` from checkout
            // until after commit.
            let candidates: Vec<EvictionCandidate> = self
                .sessions
                .iter()
                .filter(|(s, e)| {
                    **s != keep
                        && e.pages > 0
                        && e.cache
                            .as_ref()
                            .is_some_and(|c| Arc::strong_count(c) == 1)
                })
                .map(|(s, e)| EvictionCandidate {
                    session: *s,
                    pages: e.pages,
                    last_touch: e.last_touch,
                })
                .collect();
            if candidates.is_empty() {
                break; // nothing (else) evictable: let it run
            }
            let victim = match self.policy.select(self.clock, &candidates) {
                Some(v) => v,
                None => break, // policy declined
            };
            if !candidates.iter().any(|c| c.session == victim) {
                break; // defensive: a policy may only pick candidates
            }
            let page_bytes = self.cfg.page_bytes();
            let entry = self.sessions.get_mut(&victim).expect("candidate exists");
            let cache = entry.cache.take().expect("candidates are cached");
            self.charged_pages -= entry.pages;
            entry.pages = 0;
            self.stats.evictions += 1;
            if let Some(tier) = self.spill.as_mut() {
                let snap = cache.snapshot();
                self.spill_stats.spills += 1;
                self.spill_stats.bytes_spilled += (snap.pages() * page_bytes) as u64;
                tier.spill(victim, snap);
            }
        }
    }

    /// Record tokens appended to a checked-out session and enforce the
    /// page budget, evicting *other* sessions (per the policy) until
    /// it holds (the active session is never evicted under itself —
    /// a single oversized session may exceed the budget alone).
    pub fn commit(&mut self, session: u64, appended: &[i32]) {
        if let Some(e) = self.sessions.get_mut(&session) {
            e.history.extend_from_slice(appended);
            // Re-charge only this session's pages (its heads are idle
            // now); every other session keeps its committed count.
            let now = e.cache.as_ref().map_or(0, |c| c.pages());
            self.charged_pages = self.charged_pages - e.pages + now;
            e.pages = now;
        }
        self.enforce_budget(session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::cache::TokenRow;

    fn cfg(capacity_pages: usize) -> KvCacheConfig {
        KvCacheConfig {
            n_layers: 1,
            n_heads: 1,
            d_head: 4,
            d_v: 4,
            block: 2,
            page_tokens: 2,
            capacity_pages,
        }
    }

    fn row() -> TokenRow {
        TokenRow {
            iq: vec![1.0; 4],
            fq: vec![0.0; 4],
            ik: vec![1.0; 4],
            fk: vec![0.0; 4],
            v: vec![1.0; 4],
        }
    }

    /// A token-indexed row with distinct values per position, so
    /// bitwise payload comparisons actually discriminate.
    fn vrow(t: usize) -> TokenRow {
        let f = |k: usize| ((t * 31 + k * 7) % 13) as f32 - 6.0;
        TokenRow {
            iq: (0..4).map(f).collect(),
            fq: (4..8).map(f).collect(),
            ik: (8..12).map(f).collect(),
            fk: (12..16).map(f).collect(),
            v: (16..20).map(f).collect(),
        }
    }

    fn cand(session: u64, pages: usize, last_touch: u64) -> EvictionCandidate {
        EvictionCandidate { session, pages, last_touch }
    }

    /// Append `n` tokens to every head of `session` and commit them.
    fn grow(store: &mut SessionStore, session: u64, n: usize) {
        let (cache, replay) = store.checkout(session);
        assert!(replay.is_empty(), "warm session needs no replay");
        for _ in 0..n {
            cache.head(0, 0).lock().unwrap().append(&row());
        }
        drop(cache);
        store.commit(session, &vec![7i32; n]);
    }

    #[test]
    fn lru_policy_picks_smallest_stamp() {
        let p = LruPolicy::new();
        let c = [cand(1, 2, 30), cand(2, 9, 10), cand(3, 1, 20)];
        assert_eq!(p.select(31, &c), Some(2));
        assert_eq!(p.select(31, &[]), None, "empty slice: nothing evictable");
    }

    #[test]
    fn largest_first_picks_most_pages_ties_by_age() {
        let p = LargestFirstPolicy::new();
        let c = [cand(1, 2, 30), cand(2, 9, 10), cand(3, 9, 5), cand(4, 1, 1)];
        // 2 and 3 tie on pages; 3 is older (stamp 5 < 10).
        assert_eq!(p.select(31, &c), Some(3));
        assert_eq!(p.select(31, &[cand(7, 4, 2)]), Some(7));
        assert_eq!(p.select(31, &[]), None);
    }

    #[test]
    fn ttl_policy_expired_oldest_then_lru_fallback() {
        let p = TtlPolicy::new(10);
        let c = [cand(1, 2, 5), cand(2, 9, 90), cand(3, 1, 50)];
        // now=95: sessions 1 (idle 90) and 3 (idle 45) are expired;
        // the oldest expired goes first.
        assert_eq!(p.select(95, &c), Some(1));
        // now=58: only session 1 is expired (idle 53 > 10).
        assert_eq!(p.select(58, &c), Some(1));
        // now=12: nothing expired (idle ≤ 10) → LRU fallback, budget
        // still closes.
        assert_eq!(p.select(12, &c), Some(1));
        let fresh = [cand(4, 3, 11), cand(5, 1, 12)];
        assert_eq!(p.select(13, &fresh), Some(4), "fallback is pure LRU");
    }

    #[test]
    fn capacity_evicts_lru_session_and_keeps_history() {
        // 2-token pages, budget 4 pages: two 4-token sessions fill it;
        // a third session evicts the least recently used (session 1).
        let mut store = SessionStore::new(cfg(4));
        grow(&mut store, 1, 4);
        grow(&mut store, 2, 4);
        assert_eq!(store.total_pages(), 4);
        assert_eq!(store.cached_sessions(), 2);
        grow(&mut store, 3, 2);
        assert_eq!(store.stats().evictions, 1);
        assert_eq!(store.cached_sessions(), 2, "one session dropped pages");
        assert!(store.total_pages() <= 4);
        // Session 1 lost its pages but not its history...
        assert_eq!(store.history_len(1), 4);
        // ...and checking it out again rebuilds: fresh cache + replay.
        let (cache, replay) = store.checkout(1);
        assert_eq!(replay, vec![7i32; 4], "full history handed back");
        assert_eq!(cache.len(), 0, "fresh cache, caller replays");
        assert_eq!(store.stats().rebuilds, 1);
    }

    #[test]
    fn active_session_never_self_evicts() {
        // One session alone may exceed the budget: nothing else to
        // evict, so the store lets it run rather than thrash.
        let mut store = SessionStore::new(cfg(2));
        grow(&mut store, 5, 10); // 5 pages > budget 2
        assert_eq!(store.stats().evictions, 0);
        assert_eq!(store.total_pages(), 5);
        // A second session now triggers eviction of the first.
        grow(&mut store, 6, 2);
        assert_eq!(store.stats().evictions, 1);
        assert_eq!(store.total_pages(), 1);
    }

    #[test]
    fn checked_out_sessions_are_never_evicted() {
        // Session 1 is the LRU victim on paper, but its cache is
        // checked out (Arc held outside the store) — the candidate
        // filter must skip it and evict session 2 instead, for every
        // policy (the filter is store-side, policy-agnostic).
        let policies: [Box<dyn EvictionPolicy>; 3] = [
            Box::new(LruPolicy::new()),
            Box::new(LargestFirstPolicy::new()),
            Box::new(TtlPolicy::new(1)),
        ];
        for policy in policies {
            let mut store = SessionStore::with_policy(cfg(4), policy);
            grow(&mut store, 1, 4);
            let (held, _) = store.checkout(1);
            grow(&mut store, 2, 4);
            grow(&mut store, 3, 2); // overflow: must evict someone
            assert_eq!(store.stats().evictions, 1);
            let (_, r1) = store.checkout(1);
            assert!(r1.is_empty(), "held session kept its pages");
            let (_, r2) = store.checkout(2);
            assert_eq!(r2.len(), 4, "unheld session paid instead");
            drop(held);
        }
    }

    #[test]
    fn largest_first_store_frees_budget_in_one_eviction() {
        let mut store =
            SessionStore::with_policy(cfg(6), Box::new(LargestFirstPolicy::new()));
        grow(&mut store, 1, 2); // 1 page, oldest
        grow(&mut store, 2, 8); // 4 pages
        grow(&mut store, 3, 4); // 2 pages → 7 > 6
        // LRU would evict session 1 (freeing 1 page) and then need a
        // second victim; largest-first takes session 2 and is done.
        assert_eq!(store.stats().evictions, 1);
        assert!(store.total_pages() <= 6);
        let (_, r1) = store.checkout(1);
        assert!(r1.is_empty(), "small old session survives");
        let (_, r2) = store.checkout(2);
        assert_eq!(r2.len(), 8, "largest session was evicted");
    }

    #[test]
    fn touch_order_protects_hot_sessions() {
        let mut store = SessionStore::new(cfg(4));
        grow(&mut store, 1, 4);
        grow(&mut store, 2, 4);
        // Re-touch session 1: session 2 becomes the LRU victim.
        let _ = store.checkout(1);
        grow(&mut store, 3, 2);
        assert_eq!(store.history_len(2), 4);
        let (_, replay) = store.checkout(2);
        assert_eq!(replay.len(), 4, "evicted session 2 must replay");
        let (_, no_replay) = store.checkout(1);
        assert!(no_replay.is_empty(), "session 1 stayed cached");
    }

    #[test]
    fn charged_pages_track_live_allocation() {
        // The O(1) accounting must agree with a live walk of every
        // cached session after each commit, across growth, eviction
        // and rebuild.
        let mut store = SessionStore::new(cfg(6));
        for (s, n) in [(1u64, 3usize), (2, 5), (1, 2), (3, 4), (1, 1)] {
            grow_any(&mut store, s, n);
            let live: usize = store
                .sessions
                .values()
                .filter_map(|e| e.cache.as_ref())
                .map(|c| c.pages())
                .sum();
            assert_eq!(store.total_pages(), live, "after session {s} += {n}");
        }
    }

    /// Like `grow`, but tolerates the session having been evicted
    /// (replays its history first, as the engine would).
    fn grow_any(store: &mut SessionStore, session: u64, n: usize) {
        let (cache, replay) = store.checkout(session);
        for _ in 0..replay.len() + n {
            cache.head(0, 0).lock().unwrap().append(&row());
        }
        drop(cache);
        store.commit(session, &vec![7i32; n]);
    }

    #[test]
    fn multiple_sessions_check_out_concurrently() {
        // The batched-decode shape: every session in a batch checked
        // out up front (Arc handles), worked concurrently through the
        // per-head locks, then committed — with the store free in
        // between.
        let mut store = SessionStore::new(cfg(usize::MAX));
        let (a, ra) = store.checkout(1);
        let (b, rb) = store.checkout(2);
        assert!(ra.is_empty() && rb.is_empty());
        std::thread::scope(|s| {
            for cache in [&a, &b] {
                s.spawn(move || {
                    for _ in 0..3 {
                        cache.head(0, 0).lock().unwrap().append(&row());
                    }
                });
            }
        });
        store.commit(1, &[7, 7, 7]);
        store.commit(2, &[8, 8, 8]);
        assert_eq!(store.history_len(1), 3);
        assert_eq!(store.history_len(2), 3);
        assert_eq!(store.total_pages(), 4, "2 pages per 3-token session");
    }

    #[test]
    fn expected_pos_tracks_committed_stream_position() {
        let mut store = SessionStore::new(cfg(4));
        assert_eq!(store.expected_pos(1), 0, "unknown session starts at 0");
        grow(&mut store, 1, 3);
        assert_eq!(store.expected_pos(1), 3);
        grow(&mut store, 1, 1);
        assert_eq!(store.expected_pos(1), 4);
        // Eviction drops pages, never the stream position: the session
        // still appends at its committed length.
        grow(&mut store, 2, 6); // 3 pages: evicts session 1 (budget 4)
        assert_eq!(store.stats().evictions, 1);
        assert_eq!(store.expected_pos(1), 4, "position survives eviction");
    }

    #[test]
    fn mode_fixed_at_first_sight_and_survives_eviction() {
        let mode = SessionMode::Causal { window: Some(4) };
        let mut store = SessionStore::new(cfg(2));
        assert_eq!(store.mode_of(7), None);
        let (cache, replay) = store.checkout_mode(7, mode);
        assert!(replay.is_empty());
        assert_eq!(cache.mode(), mode, "cache allocated in session mode");
        assert_eq!(store.mode_of(7), Some(mode));
        for _ in 0..4 {
            cache.head(0, 0).lock().unwrap().append(&row());
        }
        drop(cache);
        store.commit(7, &[7; 4]);
        // Plain checkout resolves the recorded mode.
        let (again, _) = store.checkout(7);
        assert_eq!(again.mode(), mode);
        drop(again);
        // Eviction + rebuild must re-allocate in the *session's* mode,
        // not the default.
        grow(&mut store, 8, 4); // budget 2: session 7 evicted
        assert!(store.stats().evictions >= 1);
        let (fresh, replay) = store.checkout(7);
        assert_eq!(fresh.mode(), mode, "rebuilt cache keeps the mode");
        assert_eq!(replay.len(), 4);
    }

    #[test]
    fn policy_pinned_at_first_serve_and_survives_eviction() {
        let mut store = SessionStore::new(cfg(2));
        assert_eq!(store.policy_of(7), None);
        let (cache, _) = store.checkout(7);
        assert_eq!(store.policy_of(7), None, "checkout alone decides nothing");
        store.note_policy(7, 3);
        assert_eq!(store.policy_of(7), Some(3));
        store.note_policy(7, 3); // idempotent re-note
        for _ in 0..4 {
            cache.head(0, 0).lock().unwrap().append(&row());
        }
        drop(cache);
        store.commit(7, &[7; 4]);
        // Eviction drops pages, never the class.
        grow(&mut store, 8, 4); // budget 2: session 7 evicted
        assert!(store.stats().evictions >= 1);
        assert_eq!(store.policy_of(7), Some(3), "class survives eviction");
        // Unknown sessions are ignored — noting is not creating.
        store.note_policy(99, 1);
        assert_eq!(store.policy_of(99), None);
        // A journal-seeded session arrives with its class pinned.
        store.adopt(42, SessionMode::default(), 2, &[1, 2, 3], None);
        assert_eq!(store.policy_of(42), Some(2));
    }

    #[test]
    fn spilled_session_restores_without_replay() {
        let mut store = SessionStore::new(cfg(4));
        store.attach_spill_tier(Box::new(InMemorySpillTier::new()));
        grow(&mut store, 1, 4);
        grow(&mut store, 2, 4);
        grow(&mut store, 3, 2); // evicts session 1 → spilled, not lost
        assert_eq!(store.stats().evictions, 1);
        assert_eq!(store.spill_stats().spills, 1);
        assert_eq!(store.spilled_sessions(), 1);
        let want_bytes = (2 * store.config().page_bytes()) as u64;
        assert_eq!(store.spill_stats().bytes_spilled, want_bytes);
        // Checkout restores the snapshot: no replay, pages re-charged,
        // tier slot consumed, and crucially *not* a rebuild.
        let (cache, replay) = store.checkout(1);
        assert!(replay.is_empty(), "restored cache is already complete");
        assert_eq!(cache.len(), 4);
        assert_eq!(store.spill_stats().restores, 1);
        assert_eq!(store.spill_stats().bytes_restored, want_bytes);
        assert_eq!(store.spilled_sessions(), 0);
        assert_eq!(store.stats().rebuilds, 0, "restore is not a rebuild");
    }

    #[test]
    fn restore_matches_journal_replay_bitwise() {
        // The spill tier's core guarantee: restoring a snapshot and
        // replaying the history from scratch land on bitwise-identical
        // KV payloads.
        let n = 6;
        let mut spilled = SessionStore::new(cfg(4));
        spilled.attach_spill_tier(Box::new(InMemorySpillTier::new()));
        let mut replayed = SessionStore::new(cfg(4));
        for store in [&mut spilled, &mut replayed] {
            let (cache, _) = store.checkout(1);
            for t in 0..n {
                cache.head(0, 0).lock().unwrap().append(&vrow(t));
            }
            drop(cache);
            store.commit(1, &vec![7i32; n]);
            grow(store, 2, 4); // evict session 1 in both stores
            assert_eq!(store.stats().evictions, 1);
        }
        let (ca, ra) = spilled.checkout(1);
        assert!(ra.is_empty(), "spilled store restores");
        let (cb, rb) = replayed.checkout(1);
        assert_eq!(rb.len(), n, "plain store decodes from scratch");
        for t in 0..n {
            cb.head(0, 0).lock().unwrap().append(&vrow(t));
        }
        let ha = ca.head(0, 0).lock().unwrap();
        let hb = cb.head(0, 0).lock().unwrap();
        assert_eq!(ha.len(), hb.len());
        for j in 0..n {
            assert_eq!(ha.iq_row(j), hb.iq_row(j), "iq row {j}");
            assert_eq!(ha.ik_row(j), hb.ik_row(j), "ik row {j}");
            assert_eq!(ha.fk_row(j), hb.fk_row(j), "fk row {j}");
            assert_eq!(ha.v_row(j), hb.v_row(j), "v row {j}");
        }
    }

    #[test]
    fn page_accounting_stays_exact_across_spill_and_restore() {
        // The O(1) `charged_pages` must agree with a live walk after
        // every operation even when sessions bounce through the spill
        // tier, and spilled sessions must charge exactly nothing.
        let mut store = SessionStore::new(cfg(4));
        store.attach_spill_tier(Box::new(InMemorySpillTier::new()));
        for (s, n) in [(1u64, 4usize), (2, 4), (1, 2), (3, 4), (2, 2), (1, 1)] {
            grow_any(&mut store, s, n);
            let live: usize = store
                .sessions
                .values()
                .filter_map(|e| e.cache.as_ref())
                .map(|c| c.pages())
                .sum();
            assert_eq!(store.total_pages(), live, "after session {s} += {n}");
            assert!(
                store
                    .sessions
                    .values()
                    .filter(|e| e.cache.is_none())
                    .all(|e| e.pages == 0),
                "evicted/spilled sessions charge nothing"
            );
        }
        let ss = store.spill_stats();
        assert!(ss.spills > 0, "pressure must have spilled something");
        assert!(ss.restores > 0, "returning sessions must have restored");
        assert_eq!(store.stats().rebuilds, 0, "every comeback was a restore");
    }

    #[test]
    fn adopt_seeds_history_and_suffix_replays_past_checkpoint() {
        // A re-homed session with a checkpoint at 4 of 6 tokens must
        // check out replaying only the 2-token suffix.
        let c = cfg(usize::MAX);
        let mut donor = SessionStore::new(c);
        grow(&mut donor, 9, 4);
        let (snap_src, _) = donor.checkout(9);
        let snap = snap_src.snapshot();

        let mut store = SessionStore::new(c);
        let full: Vec<i32> = vec![7; 6];
        store.adopt(9, SessionMode::default(), 0, &full, Some((4, &snap)));
        assert_eq!(store.stats().adoptions, 1);
        assert_eq!(store.expected_pos(9), 6);
        let (cache, replay) = store.checkout(9);
        assert_eq!(cache.len(), 4, "checkpoint pages installed");
        assert_eq!(replay, vec![7i32; 2], "only the suffix replays");
        assert_eq!(store.stats().rebuilds, 1);
        assert_eq!(store.total_pages(), cache.pages());
    }

    #[test]
    fn adopt_without_checkpoint_replays_everything() {
        let mut store = SessionStore::new(cfg(usize::MAX));
        store.adopt(3, SessionMode::default(), 0, &[1, 2, 3, 4, 5], None);
        let (cache, replay) = store.checkout(3);
        assert_eq!(cache.len(), 0);
        assert_eq!(replay, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn adopt_is_idempotent_and_never_rewinds() {
        let mut store = SessionStore::new(cfg(usize::MAX));
        grow(&mut store, 1, 4);
        // A journal at or behind the local stream is a no-op: the
        // local lane already owns at least this much committed state.
        store.adopt(1, SessionMode::default(), 0, &[7, 7, 7], None);
        store.adopt(1, SessionMode::default(), 0, &[7, 7, 7, 7], None);
        assert_eq!(store.stats().adoptions, 0);
        assert_eq!(store.expected_pos(1), 4);
        let (_, replay) = store.checkout(1);
        assert!(replay.is_empty(), "warm cache untouched by adopt");
        // A longer journal extends the history; the warm cache stays
        // (it is a consistent prefix) and only the gap replays.
        store.adopt(1, SessionMode::default(), 0, &[7, 7, 7, 7, 9, 9], None);
        assert_eq!(store.stats().adoptions, 1);
        let (cache, replay) = store.checkout(1);
        assert_eq!(cache.len(), 4);
        assert_eq!(replay, vec![9, 9]);
    }

    #[test]
    fn adopted_checkpoint_pages_count_against_budget() {
        let c = cfg(usize::MAX);
        let mut donor = SessionStore::new(c);
        grow(&mut donor, 1, 6);
        let (src, _) = donor.checkout(1);
        let snap = src.snapshot(); // 3 pages at 2 tokens/page
        drop(src);

        let mut store = SessionStore::new(cfg(4));
        grow(&mut store, 2, 4); // 2 pages resident
        store.adopt(1, SessionMode::default(), 0, &vec![7i32; 6], Some((6, &snap)));
        // 3 + 2 = 5 pages > budget 4: the colder session 2 is evicted.
        assert_eq!(store.stats().evictions, 1);
        assert!(store.total_pages() <= 4);
        let (_, replay) = store.checkout(1);
        assert!(replay.is_empty(), "adopted session kept its checkpoint");
    }

    #[test]
    fn overlong_cache_is_dropped_and_rebuilt() {
        // An appended-but-never-committed cache (interrupted serve)
        // must not survive checkout: the store rebuilds from the
        // committed history.
        let mut store = SessionStore::new(cfg(usize::MAX));
        grow(&mut store, 1, 2);
        let (cache, _) = store.checkout(1);
        cache.head(0, 0).lock().unwrap().append(&row()); // no commit
        drop(cache);
        let (fresh, replay) = store.checkout(1);
        assert_eq!(fresh.len(), 0, "tainted cache dropped");
        assert_eq!(replay, vec![7i32; 2], "committed stream replays");
    }

    #[test]
    fn stats_track_creation() {
        let mut store = SessionStore::new(cfg(usize::MAX));
        grow(&mut store, 1, 1);
        grow(&mut store, 1, 1);
        grow(&mut store, 2, 1);
        let s = store.stats();
        assert_eq!(s.sessions_created, 2);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.rebuilds, 0);
        assert_eq!(store.sessions(), 2);
        assert_eq!(store.history_len(1), 2);
    }
}
