//! Fleet-wide session journal: the durable record a session restores
//! from when its lane dies or drains.
//!
//! # What is journaled — and the replay-bitwise contract
//!
//! A [`SessionJournal`] records, per session, the **committed token
//! stream** and the policy parameters the stream was served under (the
//! host-quantizer calibration scale and the session's
//! [`SessionMode`]) — *tokens only, never KV pages*.
//! That is enough for exact recovery because of the repo's core
//! serving invariant, pinned since the session subsystem landed
//! (`rust/tests/decode_conformance.rs`): every cached derivation is a
//! pure function of the committed token stream, and **incremental
//! decode equals full recompute bitwise at every step**. A re-homed
//! session therefore restores by replaying its journaled tokens
//! through the *same* eviction-rebuild path an evicted session already
//! uses ([`super::SessionStore::checkout`] hands back the missing
//! history as replay) — lane failover is, by construction, the
//! eviction contract applied across lanes, and the surviving stream is
//! bitwise equal to an uninterrupted sequential reference run
//! (`rust/tests/failover_conformance.rs` pins this).
//!
//! # Checkpoints
//!
//! Replay cost is `O(context)`. When configured with
//! [`SessionJournal::with_checkpoints`], the journal additionally
//! keeps, per session, one frozen θ/KV snapshot
//! ([`KvCache::snapshot`]), refreshed every `checkpoint_every`
//! committed tokens. A restore seeds the adopting store with a deep
//! copy of the snapshot and replays only the suffix past it —
//! bitwise identical to full replay (the snapshot copies every field
//! that feeds the incremental θ fold verbatim), just faster. The
//! journal itself stays authoritative on the tokens: a checkpoint is
//! an accelerator, never a source of truth.
//!
//! # Chunked prefill
//!
//! A streaming prefill (`Engine::with_prefill_chunk`) journals one
//! `record` per committed *chunk*, exactly as a monolithic prefill
//! journals one record for the whole context — the journal sees only
//! committed token spans and never needs to know about chunking. A
//! session that dies mid-prefill therefore restores up to its last
//! committed chunk boundary (position p), and the adopting lane's
//! readmitted chunk requests resume the stream from p — the journal
//! never re-serves committed rows, because replay *is* the committed
//! stream and the remaining chunks are ordinary queued requests.
//!
//! # Concurrency
//!
//! One journal is shared (`Arc`) by every lane of a fleet. `record` is
//! called inside the owning engine's commit phase; since exactly one
//! lane serves a session at a time (sticky routing, and failover
//! re-homes only *after* a lane stopped serving), per-session entries
//! are never raced. The interior `Mutex` makes cross-session access
//! from many lanes sound.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::cache::{KvCache, SessionMode};
use crate::policy::PolicyId;

/// Lifetime counters the failover metrics and tests surface.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JournalStats {
    /// Commit batches recorded (one per `record` call).
    pub records: u64,
    /// θ/KV snapshots taken.
    pub checkpoints: u64,
    /// Restores handed out, total.
    pub restores: u64,
    /// Restores that carried a checkpoint (suffix replay instead of
    /// full replay).
    pub checkpoint_restores: u64,
}

/// What a restore hands back: the full committed stream, the policy
/// scale it was served under, and — when checkpointing is on — the
/// frozen snapshot plus its stream position. The adopting store deep-
/// copies the snapshot ([`super::SessionStore::adopt`]); the journal
/// keeps its own copy frozen.
#[derive(Debug, Clone)]
pub struct SessionRestore {
    pub tokens: Vec<i32>,
    /// Calibration scale the stream was served at — the adopting lane
    /// must be configured identically or the derivation would diverge;
    /// [`SessionJournal::restore_for`] enforces this.
    pub cal_scale: f32,
    /// The session's attention mode, fixed at its first journaled
    /// commit. The adopting store seeds its entry with it, so a
    /// re-homed causal session keeps refusing bidirectional steps
    /// (and vice versa) exactly like the lane it left.
    pub mode: SessionMode,
    /// The session's pruning-policy class, fixed at its first journaled
    /// commit alongside the mode. The adopting store pins it, so a
    /// re-homed session keeps serving — and keeps refusing mismatched
    /// claims — at exactly the class it started with.
    pub policy: PolicyId,
    /// `(position, snapshot)`: the snapshot holds exactly `position`
    /// tokens of cached state; `tokens[position..]` is the replay
    /// suffix.
    pub checkpoint: Option<(usize, Arc<KvCache>)>,
}

#[derive(Debug)]
struct JournalEntry {
    tokens: Vec<i32>,
    cal_scale: f32,
    mode: SessionMode,
    policy: PolicyId,
    checkpoint: Option<(usize, Arc<KvCache>)>,
}

/// The journal proper. See the module docs for the contract.
#[derive(Debug)]
pub struct SessionJournal {
    inner: Mutex<HashMap<u64, JournalEntry>>,
    /// Snapshot refresh period in committed tokens; 0 disables
    /// checkpointing (tokens-only journal, full replay on restore).
    checkpoint_every: usize,
    stats: Mutex<JournalStats>,
}

impl SessionJournal {
    /// Tokens-only journal: restores replay the full stream.
    pub fn new() -> Self {
        Self::with_checkpoints(0)
    }

    /// Journal that additionally snapshots each session's θ/KV state
    /// every `checkpoint_every` committed tokens (0 = off), so
    /// restores replay only the suffix past the last snapshot.
    pub fn with_checkpoints(checkpoint_every: usize) -> Self {
        Self {
            inner: Mutex::new(HashMap::new()),
            checkpoint_every,
            stats: Mutex::new(JournalStats::default()),
        }
    }

    pub fn stats(&self) -> JournalStats {
        *self.stats.lock().unwrap()
    }

    /// Sessions the journal knows.
    pub fn sessions(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// Committed stream length of `session` (0 if unknown) — what a
    /// lane compares its local history against to decide whether a
    /// session was re-homed to it.
    pub fn len(&self, session: u64) -> usize {
        self.inner.lock().unwrap().get(&session).map_or(0, |e| e.tokens.len())
    }

    /// Record a commit: `appended` extends `session`'s journaled
    /// stream, served at `cal_scale` in `mode` at pruning class
    /// `policy` (all fixed at the first record — the engine refuses
    /// mismatching steps before they reach the journal). Returns the
    /// new stream length. Called by the owning lane inside its commit
    /// phase, so the journal is always at least as current as any
    /// response the fleet has produced.
    pub fn record(
        &self,
        session: u64,
        appended: &[i32],
        cal_scale: f32,
        mode: SessionMode,
        policy: PolicyId,
    ) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let e = inner.entry(session).or_insert_with(|| JournalEntry {
            tokens: Vec::new(),
            cal_scale,
            mode,
            policy,
            checkpoint: None,
        });
        debug_assert_eq!(
            e.cal_scale.to_bits(),
            cal_scale.to_bits(),
            "session {session}: policy scale changed mid-stream"
        );
        debug_assert_eq!(
            e.mode, mode,
            "session {session}: mode changed mid-stream"
        );
        debug_assert_eq!(
            e.policy, policy,
            "session {session}: pruning class changed mid-stream"
        );
        e.tokens.extend_from_slice(appended);
        let len = e.tokens.len();
        drop(inner);
        self.stats.lock().unwrap().records += 1;
        len
    }

    /// Whether `session` is due for a fresh snapshot: checkpointing is
    /// on and at least `checkpoint_every` tokens were committed past
    /// the last one. The engine checks this after a commit and, when
    /// true, hands the live cache to [`SessionJournal::checkpoint`].
    pub fn wants_checkpoint(&self, session: u64) -> bool {
        if self.checkpoint_every == 0 {
            return false;
        }
        let inner = self.inner.lock().unwrap();
        inner.get(&session).is_some_and(|e| {
            let at = e.checkpoint.as_ref().map_or(0, |(at, _)| *at);
            e.tokens.len() >= at + self.checkpoint_every
        })
    }

    /// Snapshot `cache` as `session`'s checkpoint. The cache must hold
    /// exactly the journaled stream (call between decode steps, right
    /// after the commit that made the session due) — a mismatched
    /// length is refused, keeping the previous checkpoint.
    pub fn checkpoint(&self, session: u64, cache: &KvCache) {
        let snap = cache.snapshot(); // deep copy outside the map lock
        let at = snap.len();
        let mut inner = self.inner.lock().unwrap();
        let Some(e) = inner.get_mut(&session) else { return };
        if at != e.tokens.len() {
            return; // cache not at the committed stream position
        }
        e.checkpoint = Some((at, Arc::new(snap)));
        drop(inner);
        self.stats.lock().unwrap().checkpoints += 1;
    }

    /// Restore `session` for an adopting lane running at `cal_scale`.
    /// Returns `None` when the session is unknown; errs when the lane's
    /// policy scale differs from the one the stream was served under
    /// (replaying under different parameters would diverge the
    /// derivation, silently — refusing is the only safe answer).
    pub fn restore_for(
        &self,
        session: u64,
        cal_scale: f32,
    ) -> anyhow::Result<Option<SessionRestore>> {
        let inner = self.inner.lock().unwrap();
        let Some(e) = inner.get(&session) else { return Ok(None) };
        anyhow::ensure!(
            e.cal_scale.to_bits() == cal_scale.to_bits(),
            "session {session}: journaled at calibration scale {} but the \
             adopting lane runs at {} — refusing a divergent replay",
            e.cal_scale,
            cal_scale,
        );
        let restore = SessionRestore {
            tokens: e.tokens.clone(),
            cal_scale: e.cal_scale,
            mode: e.mode,
            policy: e.policy,
            checkpoint: e.checkpoint.clone(),
        };
        drop(inner);
        let mut stats = self.stats.lock().unwrap();
        stats.restores += 1;
        stats.checkpoint_restores += u64::from(restore.checkpoint.is_some());
        Ok(Some(restore))
    }
}

#[cfg(test)]
mod tests {
    use super::super::cache::TokenRow;
    use super::*;

    fn row() -> TokenRow {
        TokenRow {
            iq: vec![1.0; 4],
            fq: vec![0.0; 4],
            ik: vec![1.0; 4],
            fk: vec![0.0; 4],
            v: vec![1.0; 4],
        }
    }

    fn cache_with(n: usize) -> KvCache {
        let cache = KvCache::new(1, 1, 4, 4, 2, 2);
        for _ in 0..n {
            cache.head(0, 0).lock().unwrap().append(&row());
        }
        cache
    }

    #[test]
    fn records_accumulate_the_stream() {
        let j = SessionJournal::new();
        assert_eq!(j.len(7), 0);
        assert_eq!(j.record(7, &[1, 2], 1.0, SessionMode::default(), 0), 2);
        assert_eq!(j.record(7, &[3], 1.0, SessionMode::default(), 0), 3);
        assert_eq!(j.len(7), 3);
        assert_eq!(j.sessions(), 1);
        let r = j.restore_for(7, 1.0).unwrap().expect("known session");
        assert_eq!(r.tokens, vec![1, 2, 3]);
        assert!(r.checkpoint.is_none());
        assert_eq!(j.stats().records, 2);
        assert_eq!(j.stats().restores, 1);
    }

    #[test]
    fn unknown_session_restores_none() {
        let j = SessionJournal::new();
        assert!(j.restore_for(99, 1.0).unwrap().is_none());
    }

    #[test]
    fn policy_scale_mismatch_is_refused() {
        let j = SessionJournal::new();
        j.record(1, &[5], 0.5, SessionMode::default(), 0);
        assert!(j.restore_for(1, 1.0).is_err());
        assert!(j.restore_for(1, 0.5).unwrap().is_some());
    }

    #[test]
    fn checkpoint_cadence_and_refresh() {
        let j = SessionJournal::with_checkpoints(4);
        j.record(1, &[1, 2, 3], 1.0, SessionMode::default(), 0);
        assert!(!j.wants_checkpoint(1), "3 < 4 tokens since last");
        j.record(1, &[4], 1.0, SessionMode::default(), 0);
        assert!(j.wants_checkpoint(1));
        j.checkpoint(1, &cache_with(4));
        assert!(!j.wants_checkpoint(1), "fresh checkpoint at 4");
        j.record(1, &[5, 6, 7], 1.0, SessionMode::default(), 0);
        assert!(!j.wants_checkpoint(1), "7 - 4 < 4");
        j.record(1, &[8], 1.0, SessionMode::default(), 0);
        assert!(j.wants_checkpoint(1));
        let r = j.restore_for(1, 1.0).unwrap().unwrap();
        let (at, snap) = r.checkpoint.expect("checkpointed");
        assert_eq!(at, 4);
        assert_eq!(snap.len(), 4);
        assert_eq!(r.tokens.len(), 8, "tokens stay authoritative");
        assert_eq!(j.stats().checkpoints, 1);
        assert_eq!(j.stats().checkpoint_restores, 1);
    }

    #[test]
    fn mispositioned_checkpoint_is_refused() {
        let j = SessionJournal::with_checkpoints(2);
        j.record(1, &[1, 2, 3], 1.0, SessionMode::default(), 0);
        j.checkpoint(1, &cache_with(2)); // cache behind the stream
        let r = j.restore_for(1, 1.0).unwrap().unwrap();
        assert!(r.checkpoint.is_none(), "stale-length snapshot refused");
        j.checkpoint(1, &cache_with(3));
        let r = j.restore_for(1, 1.0).unwrap().unwrap();
        assert_eq!(r.checkpoint.unwrap().0, 3);
    }

    #[test]
    fn mode_round_trips_through_restore() {
        let j = SessionJournal::new();
        let causal = SessionMode::Causal { window: Some(8) };
        j.record(1, &[1, 2], 1.0, causal, 2);
        j.record(2, &[3], 1.0, SessionMode::default(), 0);
        let r1 = j.restore_for(1, 1.0).unwrap().unwrap();
        assert_eq!(r1.mode, causal, "causal session restores causal");
        assert_eq!(r1.policy, 2, "pruning class restores with the mode");
        let r2 = j.restore_for(2, 1.0).unwrap().unwrap();
        assert_eq!(r2.mode, SessionMode::Bidirectional);
        assert_eq!(r2.policy, 0);
    }

    #[test]
    fn zero_period_never_wants_checkpoints() {
        let j = SessionJournal::new();
        j.record(1, &[1, 2, 3, 4, 5], 1.0, SessionMode::default(), 0);
        assert!(!j.wants_checkpoint(1));
    }
}
