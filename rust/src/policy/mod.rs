//! # Per-request pruning policies: classes, table, router
//!
//! Every request in the serving stack used to run at one global
//! (rho, tau) fixed when the engine was built. The paper's premise is
//! that attention redundancy varies *at run time*, so this subsystem
//! makes the pruning knobs per-request state — the same promotion
//! `inv_scale` (calibration) and [`crate::session::SessionMode`] went
//! through before it:
//!
//! * [`PruningPolicy`] — the value type: `(rho, tau, head_budget)`.
//!   `rho`/`tau` override the kernel's configured knobs wholesale;
//!   `head_budget` caps how many heads *per layer* may survive the
//!   early head decision, folded in as `tau = +inf` for head indices
//!   at or past the budget (a forced early prune, which the sequential
//!   reference expresses with the same parameters — so budgeted
//!   execution stays bitwise on the reference contract). `rho` is
//!   clamped to `[-1, 1]` exactly like
//!   [`crate::sim::SparsityEngine::new`] and
//!   [`crate::attention::hdp::row_threshold`] clamp it.
//! * [`PolicyTable`] — the named request classes a fleet shares:
//!   `global` (id 0, mirroring the engine's configured knobs — the
//!   single-global-policy baseline), `exact` (no pruning), `balanced`
//!   and `aggressive`, extendable/overridable from a
//!   `name:rho,tau[,budget]` spec string (`--policy-table`). Requests
//!   name classes by [`PolicyId`] (their index in the table), which
//!   keeps the id `Copy + Eq` for typed refusals.
//! * [`PolicyRouter`] — picks a class per request when the client
//!   didn't. [`StaticRouter`] always answers one class;
//!   [`StatsRouter`] decides from [`PolicyFeatures`] — cheap integer
//!   statistics (token count, quantized score mass/spread) the score
//!   pipeline's own derivation already produces. Both are pure
//!   functions of their inputs: routing is deterministic and
//!   unit-testable, never a scheduling side effect.
//!
//! ## How a policy flows through the stack
//!
//! A request carries an optional [`PolicyId`]
//! ([`crate::coordinator::Request::with_policy`] / `--policy-class`).
//! The engine resolves the *effective* class before touching any
//! state: an explicit id wins; otherwise the router (when installed)
//! routes the request's features; otherwise the `global` class. For
//! decode sessions the class is fixed at the session's first request —
//! recorded in the session store, journaled with the stream, and
//! restored on eviction replay, spill restore and lane failover — and
//! a later step naming a *different* class is refused pre-mutation
//! with the typed, non-retryable
//! [`crate::coordinator::RejectReason::PolicyMismatch`], exactly like
//! a mode mismatch. Co-batched requests with different policies each
//! run their own knobs, bitwise equal to a sequential reference run at
//! that policy (pinned by `rust/tests/policy_conformance.rs`).

mod router;
mod table;

pub use router::{PolicyFeatures, PolicyRouter, StaticRouter, StatsRouter};
pub use table::{PolicyTable, GLOBAL_CLASS};

use crate::attention::hdp::HdpParams;

/// Index of a class in the fleet-shared [`PolicyTable`] — the form a
/// policy travels in (on requests, in session entries, in journal
/// records). `u32` keeps it `Copy + Eq + Hash`, so typed refusals can
/// carry both sides of a mismatch.
pub type PolicyId = u32;

/// One request class's pruning knobs. See the module docs for how the
/// three fields act; construction clamps `rho` onto the same `[-1, 1]`
/// domain the sparsity engine and `row_threshold` enforce, so a table
/// entry can never disagree with what the kernel actually runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PruningPolicy {
    /// Block-pruning aggressiveness (Algorithm 2's Θ interpolation
    /// knob), clamped to `[-1, 1]`: `-1` keeps every block, `1` keeps
    /// only each row's argmax.
    pub rho: f32,
    /// Early head-pruning threshold: a head survives iff
    /// `theta_head > tau`. `NEG_INFINITY` keeps every head.
    pub tau: f32,
    /// Per-layer cap on surviving heads: head indices `>= budget` run
    /// at `tau = +inf` (forced early prune — zero output, no FUM /
    /// softmax / P·V work). `None` = no cap.
    pub head_budget: Option<usize>,
}

impl PruningPolicy {
    /// Policy with `rho` clamped onto the engine's domain (see
    /// [`PruningPolicy::clamped`]).
    pub fn new(rho: f32, tau: f32, head_budget: Option<usize>) -> Self {
        Self { rho, tau, head_budget }.clamped()
    }

    /// `rho` folded onto `[-1, 1]` — **bitwise** the clamp
    /// [`crate::sim::SparsityEngine::new`] applies (and
    /// [`crate::attention::hdp::row_threshold`] re-applies), so a
    /// policy's stored `rho` always equals the value the sparsity
    /// engine would run at. `tau` and the budget pass through
    /// untouched (`tau` has no domain clamp anywhere in the stack).
    pub fn clamped(self) -> Self {
        Self { rho: self.rho.clamp(-1.0, 1.0), ..self }
    }

    /// The kernel parameters head `head` of a layer runs at under this
    /// policy: `rho`/`tau` replace the base knobs, everything else
    /// (`inv_scale`, `use_ff`, `use_hw_softmax`, `block`) keeps the
    /// engine's configuration. A head at or past the budget gets
    /// `tau = +inf`, which the kernel's early decision
    /// (`theta_head > tau`) can never pass — the forced prune is
    /// expressed *in the parameters*, so the sequential reference run
    /// at the same parameters is bitwise identical by construction.
    pub fn params_for_head(&self, head: usize, base: HdpParams) -> HdpParams {
        let tau = match self.head_budget {
            Some(budget) if head >= budget => f32::INFINITY,
            _ => self.tau,
        };
        HdpParams { rho: self.rho, tau, ..base }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_is_bitwise_the_sparsity_engine_clamp() {
        for rho in [
            -2.0f32,
            -1.0 - f32::EPSILON,
            -1.0,
            -0.3,
            0.0,
            0.4,
            1.0,
            1.0 + f32::EPSILON,
            100.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
        ] {
            let p = PruningPolicy::new(rho, 0.0, None);
            assert_eq!(
                p.rho.to_bits(),
                rho.clamp(-1.0, 1.0).to_bits(),
                "rho={rho}"
            );
        }
    }

    #[test]
    fn budget_folds_to_infinite_tau_past_the_cap() {
        let base = HdpParams::default();
        let p = PruningPolicy::new(0.5, 0.25, Some(2));
        for head in 0..2 {
            let hp = p.params_for_head(head, base);
            assert_eq!(hp.tau.to_bits(), 0.25f32.to_bits());
            assert_eq!(hp.rho.to_bits(), 0.5f32.to_bits());
        }
        for head in 2..6 {
            let hp = p.params_for_head(head, base);
            assert_eq!(hp.tau, f32::INFINITY, "head {head} past budget");
        }
        // No budget: every head gets the policy's tau.
        let open = PruningPolicy::new(0.5, 0.25, None);
        assert_eq!(open.params_for_head(99, base).tau.to_bits(), 0.25f32.to_bits());
    }

    #[test]
    fn params_for_head_preserves_base_execution_knobs() {
        let base = HdpParams {
            inv_scale: 0.125,
            use_ff: true,
            use_hw_softmax: true,
            ..Default::default()
        };
        let hp = PruningPolicy::new(0.9, 1.0, Some(1)).params_for_head(0, base);
        assert_eq!(hp.inv_scale.to_bits(), base.inv_scale.to_bits());
        assert!(hp.use_ff);
        assert!(hp.use_hw_softmax);
        assert_eq!(hp.block, base.block);
    }
}
