//! Per-request class selection for requests that didn't name one.
//!
//! A [`PolicyRouter`] is a *pure function* from [`PolicyFeatures`] to a
//! [`PolicyId`] — no clocks, no RNG, no scheduling state — so the class
//! a request runs at is reproducible from the request alone, and the
//! conformance harness can re-derive it when building the sequential
//! reference. The features are integer statistics the score pipeline
//! already computes: the request's token count plus the mass/spread of
//! the quantized integer Q field `derive_head_inputs` produces for the
//! probe head (layer 0, head 0). Quantized field values are exact
//! small integers (stored in f32 on the grid), so the accumulations
//! below are exact integer arithmetic — bit-stable across platforms.

use std::fmt;

use super::PolicyId;

/// Cheap, exact integer features of one request, fed to a
/// [`PolicyRouter`]. See [`PolicyFeatures::from_int_field`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PolicyFeatures {
    /// Number of input tokens (rows of the quantized Q field).
    pub token_count: u64,
    /// Σ|q| over the probe head's quantized integer Q field — total
    /// score mass; large mass means strong, concentrated activations.
    pub mass: u64,
    /// `n·Σq² − (Σ|q|)²` — `n²` times the variance of `|q|` (exact,
    /// since the field holds integers). Zero means perfectly flat
    /// magnitudes; large means a few dominant entries.
    pub spread: u64,
}

impl PolicyFeatures {
    /// Derive features from a quantized integer field (the `iq` tensor
    /// from `derive_head_inputs`, whose entries are exact integers on
    /// the quant grid). Saturates at `u64::MAX` rather than wrapping so
    /// the decision stays deterministic for adversarially long inputs.
    pub fn from_int_field(token_count: usize, ints: &[f32]) -> Self {
        let mut mass: u128 = 0;
        let mut m2: u128 = 0;
        for &q in ints {
            let a = q.abs() as u128;
            mass += a;
            m2 += a * a;
        }
        let n = ints.len() as u128;
        let spread = (n * m2).saturating_sub(mass * mass);
        Self {
            token_count: token_count as u64,
            mass: u64::try_from(mass).unwrap_or(u64::MAX),
            spread: u64::try_from(spread).unwrap_or(u64::MAX),
        }
    }
}

/// Maps a request's [`PolicyFeatures`] to the [`PolicyId`] it should
/// run at. Implementations must be deterministic: equal features,
/// equal class — the conformance suites rely on it.
pub trait PolicyRouter: Send + Sync + fmt::Debug {
    /// The class for a request with these features.
    fn route(&self, features: &PolicyFeatures) -> PolicyId;
}

/// The trivial router: every unlabelled request runs one fixed class
/// (a table lookup done once at construction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StaticRouter(pub PolicyId);

impl PolicyRouter for StaticRouter {
    fn route(&self, _features: &PolicyFeatures) -> PolicyId {
        self.0
    }
}

/// Integer-statistics router (the msinap/dynamic-pruning idea with the
/// learned model replaced by a transparent decision rule):
///
/// 1. `token_count <= short_tokens` → `exact`. Short requests have
///    little redundancy to harvest and pruning overhead dominates.
/// 2. Otherwise, compare the field's relative spread to its mass:
///    `spread <= mass²` (coefficient of variation of `|q|` at most 1)
///    → `aggressive`. Flat score magnitudes mean attention is spread
///    thin and mostly redundant — prune hard.
/// 3. Otherwise → `balanced`. Spiky magnitudes mean a few
///    entries carry the row; prune conservatively.
///
/// All comparisons are exact integer arithmetic (widened to `u128` for
/// the square), so the decision is deterministic and platform-stable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StatsRouter {
    /// Class for rule 1 (short requests).
    pub exact: PolicyId,
    /// Class for rule 3 (spiky magnitudes).
    pub balanced: PolicyId,
    /// Class for rule 2 (flat magnitudes).
    pub aggressive: PolicyId,
    /// Token-count threshold at or below which requests route `exact`.
    pub short_tokens: u64,
}

impl StatsRouter {
    /// Router over the built-in class names of `table`, with the
    /// default short-request threshold of one 8-token block.
    pub fn from_table(table: &super::PolicyTable) -> anyhow::Result<Self> {
        Ok(Self {
            exact: table.require("exact")?,
            balanced: table.require("balanced")?,
            aggressive: table.require("aggressive")?,
            short_tokens: 8,
        })
    }
}

impl PolicyRouter for StatsRouter {
    fn route(&self, f: &PolicyFeatures) -> PolicyId {
        if f.token_count <= self.short_tokens {
            return self.exact;
        }
        let mass_sq = (f.mass as u128) * (f.mass as u128);
        if (f.spread as u128) <= mass_sq {
            self.aggressive
        } else {
            self.balanced
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{PolicyTable, PruningPolicy};
    use super::*;

    fn router() -> StatsRouter {
        let table = PolicyTable::builtin(PruningPolicy::new(0.5, 0.0, None));
        StatsRouter::from_table(&table).unwrap()
    }

    #[test]
    fn features_are_exact_integer_statistics() {
        // Field [3, -1, 2, 0]: mass = 6, Σq² = 14, spread = 4·14 − 36 = 20.
        let f = PolicyFeatures::from_int_field(2, &[3.0, -1.0, 2.0, 0.0]);
        assert_eq!(f, PolicyFeatures { token_count: 2, mass: 6, spread: 20 });
        // Flat field: zero spread.
        let flat = PolicyFeatures::from_int_field(4, &[5.0; 8]);
        assert_eq!(flat.spread, 0);
        assert_eq!(flat.mass, 40);
    }

    #[test]
    fn stats_router_is_deterministic_and_total() {
        let r = router();
        let cases = [
            PolicyFeatures { token_count: 4, mass: 100, spread: 5 },
            PolicyFeatures { token_count: 8, mass: 0, spread: 0 },
            PolicyFeatures { token_count: 9, mass: 10, spread: 100 },
            PolicyFeatures { token_count: 64, mass: 10, spread: 101 },
            PolicyFeatures { token_count: 64, mass: 10, spread: 99 },
            PolicyFeatures { token_count: u64::MAX, mass: u64::MAX, spread: u64::MAX },
        ];
        for f in cases {
            let first = r.route(&f);
            for _ in 0..32 {
                assert_eq!(r.route(&f), first, "nondeterministic for {f:?}");
            }
        }
    }

    #[test]
    fn stats_router_decision_boundaries() {
        let r = router();
        // Rule 1: at/below the short threshold → exact.
        assert_eq!(r.route(&PolicyFeatures { token_count: 8, mass: 9, spread: 999 }), r.exact);
        // Rule 2: spread == mass² sits on the flat side → aggressive.
        assert_eq!(
            r.route(&PolicyFeatures { token_count: 9, mass: 10, spread: 100 }),
            r.aggressive
        );
        // Rule 3: just past the boundary → balanced.
        assert_eq!(
            r.route(&PolicyFeatures { token_count: 9, mass: 10, spread: 101 }),
            r.balanced
        );
        // mass² widens to u128 — no overflow panic at u64::MAX mass.
        assert_eq!(
            r.route(&PolicyFeatures { token_count: 9, mass: u64::MAX, spread: u64::MAX }),
            r.aggressive
        );
    }

    #[test]
    fn static_router_ignores_features() {
        let r = StaticRouter(3);
        for f in [
            PolicyFeatures { token_count: 0, mass: 0, spread: 0 },
            PolicyFeatures { token_count: 1 << 40, mass: 77, spread: 1 },
        ] {
            assert_eq!(r.route(&f), 3);
        }
    }
}
