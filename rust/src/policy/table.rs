//! The named, fleet-shared table of request classes.
//!
//! A [`PolicyTable`] is built once (engine construction / CLI parse)
//! and shared read-only by every lane: ids are stable for the life of
//! the fleet, so a [`PolicyId`](super::PolicyId) recorded in a session
//! entry or journal record on one lane names the same knobs after a
//! failover onto another. Class `0` is always [`GLOBAL_CLASS`] — the
//! engine's own configured knobs — so "no policy anywhere" and
//! "explicitly the global policy" are the same execution, bitwise.

use anyhow::{bail, ensure, Context, Result};

use super::{PolicyId, PruningPolicy};

/// Name of the always-present class `0`: the engine's configured
/// (rho, tau) with no head budget — the single-global-policy baseline.
pub const GLOBAL_CLASS: &str = "global";

/// An immutable table of named [`PruningPolicy`] classes, indexed by
/// [`PolicyId`]. See the [module docs](self) for the id-stability
/// contract and [`PolicyTable::parse`] for the CLI spec grammar.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyTable {
    names: Vec<String>,
    policies: Vec<PruningPolicy>,
}

impl PolicyTable {
    /// The built-in classes, with `global` (id 0) mirroring the
    /// engine's configured knobs:
    ///
    /// | id | name         | rho  | tau   | head budget |
    /// |----|--------------|------|-------|-------------|
    /// | 0  | `global`     | —    | —     | engine knobs, no budget |
    /// | 1  | `exact`      | -1.0 | -inf  | none (keep everything) |
    /// | 2  | `balanced`   | 0.4  | 0.0   | none |
    /// | 3  | `aggressive` | 0.9  | 0.5   | 2 heads/layer |
    pub fn builtin(global: PruningPolicy) -> Self {
        let mut t = Self { names: Vec::new(), policies: Vec::new() };
        t.insert(GLOBAL_CLASS, global);
        t.insert("exact", PruningPolicy::new(-1.0, f32::NEG_INFINITY, None));
        t.insert("balanced", PruningPolicy::new(0.4, 0.0, None));
        t.insert("aggressive", PruningPolicy::new(0.9, 0.5, Some(2)));
        t
    }

    /// Extend/override the built-in table from a `--policy-table` spec:
    /// semicolon-separated `name:rho,tau[,head_budget]` entries, e.g.
    /// `bulk:0.8,0.25;pinned:0.0,-inf,4`. A known name (other than
    /// `global`, which always mirrors the engine knobs) replaces that
    /// class in place — its id is unchanged; a new name appends.
    /// Malformed entries are typed parse errors, refused before any
    /// engine is built.
    pub fn parse(spec: &str, global: PruningPolicy) -> Result<Self> {
        let mut t = Self::builtin(global);
        for entry in spec.split(';').filter(|e| !e.trim().is_empty()) {
            let entry = entry.trim();
            let (name, knobs) = entry.split_once(':').with_context(|| {
                format!("policy-table entry '{entry}': expected name:rho,tau[,head_budget]")
            })?;
            let name = name.trim();
            ensure!(!name.is_empty(), "policy-table entry '{entry}': empty class name");
            ensure!(
                name != GLOBAL_CLASS,
                "policy-table entry '{entry}': class '{GLOBAL_CLASS}' always mirrors the \
                 engine's --rho/--tau knobs and cannot be redefined"
            );
            let parts: Vec<&str> = knobs.split(',').map(str::trim).collect();
            ensure!(
                parts.len() == 2 || parts.len() == 3,
                "policy-table entry '{entry}': expected rho,tau or rho,tau,head_budget, \
                 got {} field(s)",
                parts.len()
            );
            let rho: f32 = parts[0]
                .parse()
                .with_context(|| format!("policy-table entry '{entry}': bad rho '{}'", parts[0]))?;
            ensure!(
                !rho.is_nan(),
                "policy-table entry '{entry}': rho must not be NaN"
            );
            let tau: f32 = parts[1]
                .parse()
                .with_context(|| format!("policy-table entry '{entry}': bad tau '{}'", parts[1]))?;
            ensure!(
                !tau.is_nan(),
                "policy-table entry '{entry}': tau must not be NaN"
            );
            let head_budget = match parts.get(2) {
                None => None,
                Some(b) => {
                    let budget: usize = b.parse().with_context(|| {
                        format!("policy-table entry '{entry}': bad head_budget '{b}'")
                    })?;
                    ensure!(
                        budget > 0,
                        "policy-table entry '{entry}': head_budget 0 would prune every \
                         head; use tau=inf on an explicit class if that is really intended"
                    );
                    Some(budget)
                }
            };
            t.insert(name, PruningPolicy::new(rho, tau, head_budget));
        }
        Ok(t)
    }

    /// Insert-or-replace by name (replace keeps the existing id).
    fn insert(&mut self, name: &str, policy: PruningPolicy) {
        let policy = policy.clamped();
        match self.names.iter().position(|n| n == name) {
            Some(i) => self.policies[i] = policy,
            None => {
                self.names.push(name.to_string());
                self.policies.push(policy);
            }
        }
    }

    /// Resolve a class name (as typed on `--policy-class`) to its id.
    pub fn id_of(&self, name: &str) -> Option<PolicyId> {
        self.names.iter().position(|n| n == name).map(|i| i as PolicyId)
    }

    /// Like [`PolicyTable::id_of`] but a typed error naming the known
    /// classes — the CLI-facing lookup.
    pub fn require(&self, name: &str) -> Result<PolicyId> {
        match self.id_of(name) {
            Some(id) => Ok(id),
            None => bail!(
                "unknown policy class '{name}' (known classes: {})",
                self.names.join(", ")
            ),
        }
    }

    /// The class name for an id (for reports and error messages).
    pub fn name_of(&self, id: PolicyId) -> Option<&str> {
        self.names.get(id as usize).map(String::as_str)
    }

    /// The knobs for an id.
    pub fn get(&self, id: PolicyId) -> Option<PruningPolicy> {
        self.policies.get(id as usize).copied()
    }

    /// Number of classes (ids are `0..len`).
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// Always false — `global` is always present.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Iterate `(id, name, policy)` in id order.
    pub fn iter(&self) -> impl Iterator<Item = (PolicyId, &str, PruningPolicy)> {
        self.names
            .iter()
            .zip(&self.policies)
            .enumerate()
            .map(|(i, (n, p))| (i as PolicyId, n.as_str(), *p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn global() -> PruningPolicy {
        PruningPolicy::new(0.6, 0.1, None)
    }

    #[test]
    fn builtin_has_global_at_id_zero() {
        let t = PolicyTable::builtin(global());
        assert_eq!(t.id_of(GLOBAL_CLASS), Some(0));
        assert_eq!(t.get(0), Some(global()));
        assert_eq!(t.len(), 4);
        for name in ["exact", "balanced", "aggressive"] {
            assert!(t.id_of(name).is_some(), "{name} missing");
        }
        let exact = t.get(t.id_of("exact").unwrap()).unwrap();
        assert_eq!(exact.rho, -1.0);
        assert_eq!(exact.tau, f32::NEG_INFINITY);
        assert_eq!(exact.head_budget, None);
    }

    #[test]
    fn parse_appends_and_overrides_without_moving_ids() {
        let t = PolicyTable::parse("bulk:0.8,0.25;balanced:0.5,0.0,4", global()).unwrap();
        // Override kept balanced's builtin id…
        let builtin = PolicyTable::builtin(global());
        assert_eq!(t.id_of("balanced"), builtin.id_of("balanced"));
        let b = t.get(t.id_of("balanced").unwrap()).unwrap();
        assert_eq!(b.rho, 0.5);
        assert_eq!(b.head_budget, Some(4));
        // …and the new class appended past the builtins.
        assert_eq!(t.id_of("bulk"), Some(builtin.len() as PolicyId));
        assert_eq!(t.len(), builtin.len() + 1);
    }

    #[test]
    fn parse_clamps_rho_onto_the_engine_domain() {
        let t = PolicyTable::parse("wild:7.5,0.0", global()).unwrap();
        let w = t.get(t.id_of("wild").unwrap()).unwrap();
        assert_eq!(w.rho.to_bits(), 1.0f32.to_bits());
    }

    #[test]
    fn parse_refuses_malformed_entries_with_typed_messages() {
        let cases = [
            ("noknobs", "expected name:rho,tau"),
            (":0.5,0.0", "empty class name"),
            ("a:0.5", "got 1 field"),
            ("a:0.5,0.0,3,9", "got 4 field"),
            ("a:x,0.0", "bad rho"),
            ("a:0.5,y", "bad tau"),
            ("a:0.5,0.0,many", "bad head_budget"),
            ("a:0.5,0.0,0", "head_budget 0"),
            ("a:NaN,0.0", "must not be NaN"),
            ("global:0.5,0.0", "cannot be redefined"),
        ];
        for (spec, needle) in cases {
            let err = PolicyTable::parse(spec, global()).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains(needle),
                "spec '{spec}': message '{msg}' missing '{needle}'"
            );
        }
    }

    #[test]
    fn require_names_known_classes_on_unknown() {
        let t = PolicyTable::builtin(global());
        let msg = format!("{:#}", t.require("warp").unwrap_err());
        assert!(msg.contains("unknown policy class 'warp'"), "{msg}");
        assert!(msg.contains("exact"), "{msg}");
        assert_eq!(t.require("aggressive").unwrap(), t.id_of("aggressive").unwrap());
    }

    #[test]
    fn iter_is_id_ordered() {
        let t = PolicyTable::builtin(global());
        let ids: Vec<PolicyId> = t.iter().map(|(id, _, _)| id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(t.iter().next().unwrap().1, GLOBAL_CLASS);
    }
}
