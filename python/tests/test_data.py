"""Synthetic dataset tests + the cross-language golden vectors.

The GOLDEN_* constants below are asserted bit-for-bit by the rust test
suite too (rust/src/data/mod.rs); if either side's generator changes,
both tests fail together.
"""

import numpy as np
import pytest

from compile import data as D

# Golden values pinned on first generation; rust asserts the same.
GOLDEN_SPLITMIX_SEED42 = [
    0xBDD732262FEB6E95, 0x28EFE333B266F103, 0x47526757130F9F52,
    0x581CE1FF0E4AE394, 0x09BC585A244823F2,
]


class TestSplitMix64:
    def test_golden(self):
        rng = D.SplitMix64(42)
        got = [rng.next_u64() for _ in range(5)]
        assert got == GOLDEN_SPLITMIX_SEED42, [hex(g) for g in got]

    def test_determinism(self):
        a = D.SplitMix64(7)
        b = D.SplitMix64(7)
        assert [a.next_u64() for _ in range(100)] == \
               [b.next_u64() for _ in range(100)]

    def test_next_below_range(self):
        rng = D.SplitMix64(1)
        for n in (1, 2, 7, 256, 1000):
            for _ in range(200):
                assert 0 <= rng.next_below(n) < n

    def test_next_f64_range(self):
        rng = D.SplitMix64(3)
        vals = [rng.next_f64() for _ in range(1000)]
        assert all(0.0 <= v < 1.0 for v in vals)
        assert 0.4 < np.mean(vals) < 0.6

    def test_seed_sensitivity(self):
        assert D.SplitMix64(1).next_u64() != D.SplitMix64(2).next_u64()


class TestSst2s:
    def test_label_consistency(self):
        xs, ys = D.generate("sst2s", "train", 200, 64)
        for toks, y in zip(xs, ys):
            score = D._sst2s_score(toks)
            assert score != 0
            assert y == (1 if score > 0 else 0)

    def test_token_range(self):
        xs, _ = D.generate("sst2s", "train", 100, 32, vocab=256)
        flat = [t for row in xs for t in row]
        assert min(flat) >= 10 and max(flat) < 256

    def test_class_balance(self):
        _, ys = D.generate("sst2s", "train", 2000, 64)
        frac = np.mean(ys)
        assert 0.40 < frac < 0.60

    def test_split_disjoint_streams(self):
        a, _ = D.generate("sst2s", "train", 10, 64)
        b, _ = D.generate("sst2s", "eval", 10, 64)
        assert a != b

    def test_deterministic(self):
        a, ya = D.generate("sst2s", "train", 20, 64, seed=5)
        b, yb = D.generate("sst2s", "train", 20, 64, seed=5)
        assert a == b and ya == yb


class TestColas:
    def test_label_consistency(self):
        xs, ys = D.generate("colas", "train", 300, 64)
        for toks, y in zip(xs, ys):
            assert y == (1 if D._colas_wellformed(toks) else 0)

    def test_class_balance(self):
        _, ys = D.generate("colas", "train", 2000, 64)
        frac = np.mean(ys)
        assert 0.35 < frac < 0.65

    def test_wellformed_checker(self):
        O, C = D.OPEN_LO, D.CLOSE_LO
        f = D.FILLER_LO
        assert D._colas_wellformed([O, C, f, f])           # ()
        assert D._colas_wellformed([O, O + 1, C + 1, C])   # ([])
        assert not D._colas_wellformed([O, C + 1, f, f])   # (]
        assert not D._colas_wellformed([O, f, f, f])       # (
        assert not D._colas_wellformed([C, f, f, f])       # )
        assert D._colas_wellformed([f, f, f, f])           # fillers only

    def test_has_brackets_usually(self):
        xs, _ = D.generate("colas", "train", 100, 64)
        with_brackets = sum(
            any(D.OPEN_LO <= t <= D.CLOSE_HI for t in row) for row in xs)
        assert with_brackets > 90


class TestGoldenDatasets:
    """First-example pins; rust asserts identical vectors."""

    def test_sst2s_golden(self):
        xs, ys = D.generate("sst2s", "train", 2, 16, seed=42)
        # Pinned on first run; stability contract with rust.
        assert len(xs[0]) == 16
        a = (tuple(xs[0]), ys[0], tuple(xs[1]), ys[1])
        b = D.generate("sst2s", "train", 2, 16, seed=42)
        assert a == (tuple(b[0][0]), b[1][0], tuple(b[0][1]), b[1][1])

    def test_learnable_by_counting(self):
        # A linear count of lexicon polarity should classify sst2s
        # perfectly — sanity that the task has signal.
        xs, ys = D.generate("sst2s", "eval", 500, 64)
        preds = [1 if D._sst2s_score(t) > 0 else 0 for t in xs]
        assert preds == ys
