"""Layer-2 model tests: shapes, variant consistency, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M
from compile.configs import BASE, TINY

QSTEP = 2.0 ** -12


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = TINY
    params = M.init_params(cfg, 1)
    xs, ys = D.generate("sst2s", "eval", 8, cfg.seq_len)
    toks = jnp.asarray(np.array(xs), jnp.int32)
    labels = jnp.asarray(np.array(ys), jnp.int32)
    return cfg, params, toks, labels


class TestShapes:
    def test_param_shapes_tiny(self, tiny_setup):
        cfg, params, _, _ = tiny_setup
        for p, (nm, sh) in zip(params, cfg.param_shapes()):
            assert p.shape == sh, nm

    def test_param_count_base(self):
        # ~3.4M params for the scaled-base stand-in.
        n = sum(int(np.prod(sh)) for _, sh in BASE.param_shapes())
        assert 2_000_000 < n < 5_000_000

    def test_forward_shapes(self, tiny_setup):
        cfg, params, toks, _ = tiny_setup
        lg = M.dense_forward(cfg, params, toks)
        assert lg.shape == (8, cfg.n_classes)
        lg2, dens, kept = M.hdp_forward(
            cfg, params, toks, 0.3, 0.0, QSTEP, 0.0, 0.0)
        assert lg2.shape == (8, cfg.n_classes)
        assert dens.shape == (cfg.n_layers, cfg.n_heads)
        assert kept.shape == (cfg.n_layers, cfg.n_heads)

    def test_probe_shapes(self, tiny_setup):
        cfg, params, toks, _ = tiny_setup
        lg, probs = M.dense_forward(cfg, params, toks[:1], return_probs=True)
        assert probs.shape == (cfg.n_layers, 1, cfg.n_heads,
                               cfg.seq_len, cfg.seq_len)
        # valid probability rows
        np.testing.assert_allclose(
            np.asarray(jnp.sum(probs, axis=-1)), 1.0, atol=1e-5)


class TestVariantConsistency:
    def test_kernel_vs_ref_path(self, tiny_setup):
        cfg, params, toks, _ = tiny_setup
        a = M.hdp_forward(cfg, params, toks, 0.3, 0.0, QSTEP, 0.0, 0.0,
                          use_kernel=True)
        b = M.hdp_forward(cfg, params, toks, 0.3, 0.0, QSTEP, 0.0, 0.0,
                          use_kernel=False)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6, atol=1e-6)

    def test_hdp_no_pruning_close_to_dense(self, tiny_setup):
        # With pruning off and exact product, HDP == dense up to
        # quantization error only.
        cfg, params, toks, _ = tiny_setup
        dense = M.dense_forward(cfg, params, toks)
        hdp, dens, kept = M.hdp_forward(
            cfg, params, toks, -1.0, -1.0, QSTEP, 1.0, 0.0)
        assert float(jnp.min(dens)) == 1.0
        assert float(jnp.min(kept)) == 1.0
        # logits differ only through quantization noise
        np.testing.assert_allclose(np.asarray(hdp), np.asarray(dense),
                                   atol=0.35)
        # labels mostly agree
        agree = jnp.mean((jnp.argmax(hdp, -1) == jnp.argmax(dense, -1))
                         .astype(jnp.float32))
        assert float(agree) >= 0.75

    def test_spatten_zero_prune_is_dense(self, tiny_setup):
        cfg, params, toks, _ = tiny_setup
        dense = M.dense_forward(cfg, params, toks)
        sp, alive = M.spatten_forward(cfg, params, toks, 0.0)
        np.testing.assert_allclose(np.asarray(sp), np.asarray(dense),
                                   rtol=1e-5, atol=1e-5)
        assert float(jnp.min(alive)) == 1.0

    def test_spatten_cascade_monotone(self, tiny_setup):
        # Once pruned, a head never comes back: alive fraction is
        # nonincreasing across layers.
        cfg, params, toks, _ = tiny_setup
        _, alive = M.spatten_forward(cfg, params, toks, 0.6)
        a = np.asarray(jnp.mean(alive, axis=1))
        assert all(x >= y - 1e-6 for x, y in zip(a, a[1:]))

    def test_topk_keep_all_close_to_dense(self, tiny_setup):
        cfg, params, toks, _ = tiny_setup
        dense = M.dense_forward(cfg, params, toks)
        tk, dens = M.topk_forward(cfg, params, toks, 1.0, QSTEP)
        assert float(jnp.min(dens)) == 1.0
        np.testing.assert_allclose(np.asarray(tk), np.asarray(dense),
                                   atol=0.35)

    def test_density_decreases_with_rho(self, tiny_setup):
        cfg, params, toks, _ = tiny_setup
        d = []
        for rho in (-0.8, 0.0, 0.6, 0.9):
            _, dens, _ = M.hdp_forward(cfg, params, toks, rho, 0.0, QSTEP,
                                       0.0, 0.0)
            d.append(float(jnp.mean(dens)))
        assert all(x >= y - 1e-9 for x, y in zip(d, d[1:]))


class TestTraining:
    def test_dense_training_reduces_loss(self, tiny_setup):
        # Overfit one fixed batch: a deterministic convergence signal
        # (full-corpus convergence is the rust E2E example's job).
        cfg, params, _, _ = tiny_setup
        params = [jnp.array(p) for p in params]
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        step = jnp.float32(0)
        xs, ys = D.generate("sst2s", "train", 16, cfg.seq_len)
        toks = jnp.asarray(np.array(xs), jnp.int32)
        labels = jnp.asarray(np.array(ys), jnp.int32)
        fn = jax.jit(lambda p, m, v, s: M.train_step(
            cfg, p, m, v, s, toks, labels, jnp.float32(1e-3)))
        losses = []
        for _ in range(30):
            params, m, v, step, loss = fn(params, m, v, step)
            losses.append(float(loss))
        assert losses[-1] < 0.5 * losses[0], losses

    def test_hdp_train_step_moves_params(self, tiny_setup):
        cfg, params, toks, labels = tiny_setup
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        nps, _, _, step, loss = M.hdp_train_step(
            cfg, params, m, v, jnp.float32(0), toks, labels,
            jnp.float32(1e-3), 0.3, 0.0, QSTEP)
        assert float(step) == 1.0
        assert np.isfinite(float(loss))
        delta = sum(float(jnp.sum(jnp.abs(a - b)))
                    for a, b in zip(nps, params))
        assert delta > 0.0

    def test_adam_step_math(self):
        # One Adam step on a scalar: matches the closed form.
        g = [jnp.asarray([2.0])]
        p = [jnp.asarray([1.0])]
        m = [jnp.asarray([0.0])]
        v = [jnp.asarray([0.0])]
        np_, nm, nv, step = M.adam_step(g, p, m, v, jnp.float32(0),
                                        jnp.float32(0.1))
        # mhat = g, vhat = g^2 -> update = lr * g/|g| = 0.1
        np.testing.assert_allclose(float(np_[0][0]), 1.0 - 0.1, rtol=1e-4)
        assert float(step) == 1.0


class TestLayerNorm:
    def test_normalizes(self):
        x = jnp.asarray(np.random.default_rng(0)
                        .normal(2.0, 3.0, (4, 8)).astype(np.float32))
        y = M.layer_norm(x, jnp.ones(8), jnp.zeros(8))
        np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1.0,
                                   atol=1e-2)
