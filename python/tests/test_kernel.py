"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the core correctness signal for the compute stack: the kernels
must match ``ref.py`` exactly on the pre-softmax path (all quantities
are exact in f32) and to float tolerance after softmax. Hypothesis
sweeps shapes, pruning ratios (both rho branches), thresholds and seeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import Q4_8, Q4_12
from compile.kernels import hdp_attention as K
from compile.kernels import ref


def make_inputs(seed, h, l, dh, qc=Q4_12, spread=2.0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(h, l, dh)).astype(np.float32)) * spread
    k = jnp.asarray(rng.normal(size=(h, l, dh)).astype(np.float32)) * spread
    v = jnp.asarray(rng.normal(size=(h, l, dh)).astype(np.float32))
    s = ref.calibrate_scale(q, qc)
    iq, fq = ref.split_int_frac(ref.quantize(q, s, qc))
    ik, fk = ref.split_int_frac(ref.quantize(k, s, qc))
    inv = 1.0 / (s * s * jnp.sqrt(jnp.float32(dh)))
    return iq, fq, ik, fk, v, inv


def vmap_ref(fn):
    """Map a single-head ref over the head axis."""
    return jax.vmap(fn)


shape_st = st.sampled_from([
    (1, 8, 4), (2, 16, 8), (2, 16, 64), (3, 32, 16), (2, 64, 32),
    (1, 128, 32), (4, 8, 8),
])


class TestHdpKernel:
    @settings(max_examples=20, deadline=None)
    @given(shape=shape_st, seed=st.integers(0, 2**31 - 1),
           rho=st.floats(-0.95, 0.95), tau=st.floats(0.0, 500.0),
           use_ff=st.sampled_from([0.0, 1.0]),
           use_hw=st.sampled_from([0.0, 1.0]))
    def test_matches_ref(self, shape, seed, rho, tau, use_ff, use_hw):
        h, l, dh = shape
        iq, fq, ik, fk, v, inv = make_inputs(seed, h, l, dh)
        out, probs, dens, kept = K.hdp_attention(
            iq, fq, ik, fk, v, rho, tau, inv, use_ff, use_hw)
        ro, rp, rd, rk = vmap_ref(
            lambda a, b, c, d, e: ref.hdp_head_ref(
                a, b, c, d, e, rho, tau, inv,
                use_ff=use_ff, use_hw_softmax=use_hw))(iq, fq, ik, fk, v)
        np.testing.assert_allclose(out, ro, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(probs, rp, rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(dens), np.asarray(rd))
        np.testing.assert_array_equal(np.asarray(kept), np.asarray(rk))

    def test_no_pruning_matches_dense_quantized(self):
        # rho = -1 => Theta = min => theta >= Theta everywhere => nothing
        # pruned (any rho > -1 would near-zero-prune theta=0 blocks);
        # use_ff=1 => exact quantized product. The result must equal
        # plain softmax attention on the quantized values.
        h, l, dh = 2, 16, 8
        iq, fq, ik, fk, v, inv = make_inputs(7, h, l, dh)
        out, _, dens, kept = K.hdp_attention(
            iq, fq, ik, fk, v, -1.0, -1.0, inv, 1.0, 0.0)
        q = iq + fq
        k = ik + fk
        ref_out = vmap_ref(lambda a, b, c: ref.exact_softmax(
            (a @ b.T) * inv) @ c)(q, k, v)
        assert float(jnp.min(dens)) == 1.0
        assert float(jnp.min(kept)) == 1.0
        np.testing.assert_allclose(out, ref_out, rtol=1e-5, atol=1e-5)

    def test_head_pruned_outputs_zero(self):
        h, l, dh = 2, 16, 8
        iq, fq, ik, fk, v, inv = make_inputs(3, h, l, dh)
        out, _, _, kept = K.hdp_attention(
            iq, fq, ik, fk, v, 0.0, 1e9, inv, 0.0, 0.0)
        assert float(jnp.max(kept)) == 0.0
        assert float(jnp.max(jnp.abs(out))) == 0.0

    def test_rho_zero_keeps_above_mean(self):
        # rho = 0 => Theta = mean: kept blocks are exactly those with
        # theta >= row mean.
        h, l, dh = 1, 16, 8
        iq, fq, ik, fk, v, inv = make_inputs(11, h, l, dh)
        _, probs, dens, _ = K.hdp_attention(
            iq, fq, ik, fk, v, 0.0, 0.0, inv, 0.0, 0.0)
        theta = ref.block_importance(iq @ jnp.swapaxes(ik, -1, -2))
        mask = (theta >= jnp.mean(theta, axis=-1, keepdims=True))
        expect = float(jnp.mean(mask.astype(jnp.float32)))
        assert abs(float(dens[0]) - expect) < 1e-6

    def test_pruned_blocks_get_zero_prob(self):
        h, l, dh = 1, 16, 8
        iq, fq, ik, fk, v, inv = make_inputs(5, h, l, dh)
        _, probs, _, _ = K.hdp_attention(
            iq, fq, ik, fk, v, 0.5, 0.0, inv, 0.0, 0.0)
        theta = ref.block_importance(iq[0] @ ik[0].T)
        mask = ref.expand_mask(ref.block_mask(theta, 0.5))
        pruned_probs = np.asarray(probs[0])[np.asarray(mask) == 0.0]
        assert pruned_probs.size > 0
        assert pruned_probs.max() < 1e-12

    def test_monotone_density_in_rho(self):
        h, l, dh = 2, 32, 16
        iq, fq, ik, fk, v, inv = make_inputs(13, h, l, dh)
        dens = []
        for rho in (-0.9, -0.5, 0.0, 0.4, 0.8):
            _, _, d, _ = K.hdp_attention(
                iq, fq, ik, fk, v, rho, 0.0, inv, 0.0, 0.0)
            dens.append(float(jnp.mean(d)))
        # Theta is nondecreasing in rho on each branch and across the
        # branch joint (rho->0- and rho->0+ both give Theta=mean).
        assert all(a >= b - 1e-9 for a, b in zip(dens, dens[1:]))


class TestIntScoreKernel:
    @settings(max_examples=15, deadline=None)
    @given(shape=shape_st, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        h, l, dh = shape
        iq, _, ik, _, _, _ = make_inputs(seed, h, l, dh)
        score, theta = K.int_score_theta(iq, ik)
        rs = jnp.einsum("hld,hmd->hlm", iq, ik)
        np.testing.assert_allclose(score, rs, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(
            theta, ref.block_importance(rs), rtol=1e-6, atol=1e-6)

    def test_integer_exactness(self):
        # Integer x integer products must be exact integers in f32.
        iq, _, ik, _, _, _ = make_inputs(0, 2, 32, 16)
        score, theta = K.int_score_theta(iq, ik)
        assert float(jnp.max(jnp.abs(score - jnp.round(score)))) == 0.0
        assert float(jnp.max(jnp.abs(theta - jnp.round(theta)))) == 0.0


class TestTopkKernel:
    @settings(max_examples=15, deadline=None)
    @given(shape=shape_st, seed=st.integers(0, 2**31 - 1),
           keep=st.floats(0.05, 1.0))
    def test_matches_ref(self, shape, seed, keep):
        h, l, dh = shape
        iq, fq, ik, fk, v, inv = make_inputs(seed, h, l, dh)
        out, probs, dens = K.topk_attention(iq, fq, ik, fk, v, keep, inv)
        ro, rp, rd = vmap_ref(
            lambda a, b, c, d, e: ref.topk_head_ref(
                a, b, c, d, e, keep, inv))(iq, fq, ik, fk, v)
        np.testing.assert_allclose(out, ro, rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(dens), np.asarray(rd))

    def test_keeps_at_least_k(self):
        # Ties can keep more, never fewer, than ceil(keep*nb) per row.
        h, l, dh = 2, 32, 16
        iq, fq, ik, fk, v, inv = make_inputs(17, h, l, dh)
        for keep in (0.1, 0.25, 0.5, 0.75):
            _, _, dens = K.topk_attention(iq, fq, ik, fk, v, keep, inv)
            nb = l // 2
            min_per_row = np.ceil(keep * nb) / nb
            assert float(jnp.min(dens)) >= min_per_row - 1e-6

    def test_keep_all(self):
        h, l, dh = 1, 16, 8
        iq, fq, ik, fk, v, inv = make_inputs(19, h, l, dh)
        _, _, dens = K.topk_attention(iq, fq, ik, fk, v, 1.0, inv)
        assert float(jnp.min(dens)) == 1.0


class TestHwSoftmax:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           rows=st.integers(1, 16), cols=st.integers(2, 64),
           scale=st.floats(0.1, 8.0))
    def test_close_to_exact(self, seed, rows, cols, scale):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32))
        x = x * scale
        approx = K.hw_softmax(x)
        exact = ref.exact_softmax(x)
        # Polynomial exp (~1e-3 rel) + Newton-refined reciprocal: rows
        # sum to ~1 and elementwise error stays small.
        np.testing.assert_allclose(approx, exact, atol=1e-2)
        np.testing.assert_allclose(
            jnp.sum(approx, axis=-1), jnp.ones(rows), atol=2e-2)

    def test_hw_exp_accuracy(self):
        x = jnp.linspace(-20.0, 3.0, 1001)
        rel = jnp.abs(ref.hw_exp(x) - jnp.exp(x)) / jnp.exp(x)
        assert float(jnp.max(rel)) < 5e-3

    def test_hw_reciprocal_accuracy(self):
        x = jnp.concatenate([jnp.linspace(1e-3, 1.0, 500),
                             jnp.linspace(1.0, 1e4, 500)])
        rel = jnp.abs(ref.hw_reciprocal(x) - 1.0 / x) * x
        assert float(jnp.max(rel)) < 5e-3


class TestQuantization:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           qc=st.sampled_from([Q4_12, Q4_8]),
           spread=st.floats(0.1, 10.0))
    def test_split_identity(self, seed, qc, spread):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32)) * spread
        s = ref.calibrate_scale(x, qc)
        q = ref.quantize(x, s, qc)
        i, f = ref.split_int_frac(q)
        np.testing.assert_array_equal(np.asarray(i + f), np.asarray(q))
        assert float(jnp.max(jnp.abs(f))) < 1.0
        assert float(jnp.max(jnp.abs(i))) <= 2**qc.int_bits
        # integer part is integral, fraction is on the grid
        np.testing.assert_array_equal(np.asarray(i), np.asarray(jnp.trunc(i)))
        step = 2.0 ** (-qc.frac_bits)
        np.testing.assert_allclose(
            np.asarray(f / step), np.round(np.asarray(f / step)), atol=1e-4)

    def test_quantize_error_bound(self):
        x = jnp.linspace(-3.0, 3.0, 1001)
        s = ref.calibrate_scale(x, Q4_12)
        q = ref.quantize(x, s, Q4_12)
        err = jnp.max(jnp.abs(q - x * s))
        assert float(err) <= 2.0 ** (-Q4_12.frac_bits) / 2 + 1e-7

    def test_sign_match(self):
        x = jnp.asarray([-2.75, -0.3, 0.0, 0.4, 3.25], jnp.float32)
        i, f = ref.split_int_frac(x)
        np.testing.assert_array_equal(np.asarray(i),
                                      np.asarray([-2.0, -0.0, 0.0, 0.0, 3.0]))
        assert all(fi == 0 or np.sign(fi) == np.sign(xi)
                   for fi, xi in zip(np.asarray(f), np.asarray(x)))


class TestThresholdFormula:
    """Algorithm 2 line 15 — both branches, bounds."""

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), rho=st.floats(-0.99, 0.99),
           nb=st.integers(2, 64))
    def test_bounds(self, seed, rho, nb):
        rng = np.random.default_rng(seed)
        theta = jnp.asarray(
            np.abs(rng.normal(size=(4, nb))).astype(np.float32)) * 10
        th = ref.row_threshold(theta, rho)
        mn = jnp.min(theta, axis=-1, keepdims=True)
        mx = jnp.max(theta, axis=-1, keepdims=True)
        mean = jnp.mean(theta, axis=-1, keepdims=True)
        if rho >= 0:
            # Theta in [mean, max]: convex combination.
            assert bool(jnp.all(th >= mean - 1e-5))
            assert bool(jnp.all(th <= mx + 1e-5))
            # at least the argmax block survives
            mask = ref.block_mask(theta, rho)
            assert bool(jnp.all(jnp.sum(mask, axis=-1) >= 1))
        else:
            # Theta = mean + |rho|(mean - min) <= mean but >= ... below mean
            # shifted toward min: Theta in [min-ish, mean].
            assert bool(jnp.all(th <= mean + 1e-5))

    def test_rho_limits(self):
        theta = jnp.asarray([[1.0, 2.0, 3.0, 10.0]])
        mean = 4.0
        np.testing.assert_allclose(ref.row_threshold(theta, 0.0), [[mean]])
        # rho -> 1: threshold -> max (only the max block kept)
        np.testing.assert_allclose(
            ref.row_threshold(theta, 0.99), [[0.99 * 10 + 0.01 * mean]])
        # rho -> -1: Theta -> -(-1)*min + 0*mean = min: everything kept
        np.testing.assert_allclose(
            ref.row_threshold(theta, -0.99),
            [[0.99 * 1.0 + 0.01 * mean]], rtol=1e-5)


class TestBlockImportance:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           l=st.sampled_from([4, 8, 16, 32]),
           block=st.sampled_from([2, 4]))
    def test_partition_sum(self, seed, l, block):
        rng = np.random.default_rng(seed)
        s = jnp.asarray(rng.normal(size=(l, l)).astype(np.float32))
        theta = ref.block_importance(s, block)
        assert theta.shape == (l // block, l // block)
        np.testing.assert_allclose(
            jnp.sum(theta), jnp.sum(jnp.abs(s)), rtol=1e-5)

    def test_known_values(self):
        s = jnp.asarray([[1., -2., 0., 0.],
                         [3., 4., 0., 1.],
                         [0., 0., -1., -1.],
                         [0., 0., 1., 1.]])
        theta = ref.block_importance(s, 2)
        np.testing.assert_array_equal(
            np.asarray(theta), np.asarray([[10., 1.], [0., 4.]]))
