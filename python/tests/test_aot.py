"""AOT pipeline tests: entry specs are self-consistent and the lowered
HLO honors the manifest contract (input count/order, output count).

These run the *lowering* (cheap) but not full artifact generation; the
round-trip through PJRT is exercised by the rust integration tests.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.configs import MODELS, TINY


def entries(cfg):
    return {name: (fn, specs, in_io, out_io)
            for name, fn, specs, in_io, out_io in aot.build_entries(cfg)}


class TestEntrySpecs:
    def test_all_entries_present(self):
        e = entries(TINY)
        assert set(e) == {
            "init", "dense_fwd", "probe_fwd", "hdp_fwd", "topk_fwd",
            "spatten_fwd", "train_step", "hdp_train_step", "hdp_attn_unit",
        }

    @pytest.mark.parametrize("name", ["init", "dense_fwd", "hdp_fwd",
                                      "topk_fwd", "spatten_fwd",
                                      "hdp_attn_unit"])
    def test_spec_matches_io(self, name):
        fn, specs, in_io, out_io = entries(TINY)[name]
        assert len(specs) == len(in_io)
        for s, d in zip(specs, in_io):
            assert tuple(d["shape"]) == s.shape
            want = jnp.int32 if d["dtype"] == "i32" else jnp.float32
            assert s.dtype == want

    def test_train_step_io_counts(self):
        fn, specs, in_io, out_io = entries(TINY)["train_step"]
        n = len(TINY.param_shapes())
        assert len(in_io) == 3 * n + 4
        assert len(out_io) == 3 * n + 2

    def test_eval_outputs_run(self):
        """Abstract-eval each entry: shapes of outputs match the manifest."""
        for name, (fn, specs, in_io, out_io) in entries(TINY).items():
            out = jax.eval_shape(fn, *specs)
            flat = jax.tree_util.tree_leaves(out)
            assert len(flat) == len(out_io), name
            for got, want in zip(flat, out_io):
                assert tuple(got.shape) == tuple(want["shape"]), (
                    name, want["name"])


class TestHloText:
    def test_lowering_produces_hlo_text(self):
        fn, specs, _, _ = entries(TINY)["hdp_attn_unit"]
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_artifacts_exist_if_built(self):
        """When artifacts/ is populated (make artifacts), the manifest and
        every referenced file must exist and be parseable."""
        adir = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts")
        mpath = os.path.join(adir, "manifest.json")
        if not os.path.exists(mpath):
            pytest.skip("artifacts not built yet")
        with open(mpath) as f:
            manifest = json.load(f)
        assert manifest["format"] == 1
        for mname, mdl in manifest["models"].items():
            assert mname in MODELS
            for ename, ent in mdl["entries"].items():
                path = os.path.join(adir, ent["file"])
                assert os.path.exists(path), ent["file"]
                with open(path) as f:
                    head = f.read(64)
                assert head.startswith("HloModule"), ent["file"]

    def test_manifest_params_match_config(self):
        adir = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts")
        mpath = os.path.join(adir, "manifest.json")
        if not os.path.exists(mpath):
            pytest.skip("artifacts not built yet")
        with open(mpath) as f:
            manifest = json.load(f)
        for mname, mdl in manifest["models"].items():
            cfg = MODELS[mname]
            want = [(nm, list(sh)) for nm, sh in cfg.param_shapes()]
            got = [(p["name"], p["shape"]) for p in mdl["params"]]
            got = [(n.replace("param.", "", 1) if n.startswith("param.")
                    else n, s) for n, s in got]
            assert [(f"{n}", s) for n, s in want] == got
