"""AOT compiler: lower every (model x entry point) to HLO **text** and
write ``artifacts/manifest.json``.

HLO text — not ``HloModuleProto.serialize()`` — is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Python runs only here, once (``make artifacts``); the rust binary is
self-contained afterwards.

Usage: ``cd python && python -m compile.aot --out ../artifacts [--force]
        [--models tiny,base]``
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import EVAL_BATCH, MODELS, TRAIN_BATCH


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(
        tuple(shape), {"f32": jnp.float32, "i32": jnp.int32}[dtype])


def io(name, shape, dtype="f32"):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


def build_entries(cfg):
    """Yield (entry_name, fn, input_specs, output_specs).

    input_specs / output_specs are manifest dicts; the positional order
    here is the PJRT calling convention the rust runtime relies on.
    """
    shapes = cfg.param_shapes()
    n = len(shapes)
    l, L, H = cfg.seq_len, cfg.n_layers, cfg.n_heads
    dh, C = cfg.d_head, cfg.n_classes

    p_in = [io(f"param.{nm}", sh) for nm, sh in shapes]
    p_specs = [spec(sh) for _, sh in shapes]
    p_out = [io(f"param.{nm}", sh) for nm, sh in shapes]
    m_in = [io(f"adam_m.{nm}", sh) for nm, sh in shapes]
    v_in = [io(f"adam_v.{nm}", sh) for nm, sh in shapes]
    tok = lambda b: io("tokens", (b, l), "i32")
    tok_s = lambda b: spec((b, l), "i32")
    f32s = lambda nm: io(nm, ())
    B, TB = EVAL_BATCH, TRAIN_BATCH

    def split_params(args, k=1):
        """args = k param-lists then the rest."""
        return [list(args[i * n:(i + 1) * n]) for i in range(k)], list(args[k * n:])

    # --- init ------------------------------------------------------------
    def init_fn(seed):
        return tuple(M.init_params(cfg, seed))
    yield ("init", init_fn, [spec((), "i32")], [io("seed", (), "i32")], p_out)

    # --- dense forward ---------------------------------------------------
    def dense_fn(*args):
        (ps,), (tokens,) = split_params(args)
        return (M.dense_forward(cfg, ps, tokens),)
    yield ("dense_fwd", dense_fn, p_specs + [tok_s(B)],
           p_in + [tok(B)], [io("logits", (B, C))])

    # --- probe (Fig. 2): dense forward returning attention probs ---------
    def probe_fn(*args):
        (ps,), (tokens,) = split_params(args)
        logits, probs = M.dense_forward(cfg, ps, tokens, return_probs=True)
        return logits, probs
    yield ("probe_fwd", probe_fn, p_specs + [tok_s(1)],
           p_in + [tok(1)],
           [io("logits", (1, C)), io("attn_probs", (L, 1, H, l, l))])

    # --- HDP forward (the headline artifact) ------------------------------
    def hdp_fn(*args):
        (ps,), rest = split_params(args)
        tokens, rho, tau, qstep, use_ff, use_hw = rest
        return M.hdp_forward(cfg, ps, tokens, rho, tau, qstep, use_ff, use_hw)
    yield ("hdp_fwd", hdp_fn,
           p_specs + [tok_s(B)] + [spec(())] * 5,
           p_in + [tok(B), f32s("rho"), f32s("tau"), f32s("qstep"),
                   f32s("use_ff"), f32s("use_hw_softmax")],
           [io("logits", (B, C)), io("kept_density", (L, H)),
            io("head_kept", (L, H))])

    # --- Top-K baseline forward -------------------------------------------
    def topk_fn(*args):
        (ps,), rest = split_params(args)
        tokens, keep_frac, qstep = rest
        return M.topk_forward(cfg, ps, tokens, keep_frac, qstep)
    yield ("topk_fwd", topk_fn,
           p_specs + [tok_s(B)] + [spec(())] * 2,
           p_in + [tok(B), f32s("keep_frac"), f32s("qstep")],
           [io("logits", (B, C)), io("kept_density", (L, H))])

    # --- SpAtten cascaded head pruning baseline ----------------------------
    def spatten_fn(*args):
        (ps,), rest = split_params(args)
        tokens, prune_frac = rest
        return M.spatten_forward(cfg, ps, tokens, prune_frac)
    yield ("spatten_fwd", spatten_fn,
           p_specs + [tok_s(B), spec(())],
           p_in + [tok(B), f32s("prune_frac")],
           [io("logits", (B, C)), io("head_alive", (L, H))])

    # --- dense train step ---------------------------------------------------
    def train_fn(*args):
        (ps, ms, vs), rest = split_params(args, 3)
        step, tokens, labels, lr = rest
        nps, nms, nvs, nstep, loss = M.train_step(
            cfg, ps, ms, vs, step, tokens, labels, lr)
        return tuple(nps) + tuple(nms) + tuple(nvs) + (nstep, loss)
    t_in = (p_in + m_in + v_in +
            [f32s("step"), tok(TB), io("labels", (TB,), "i32"), f32s("lr")])
    t_specs = (p_specs * 3 +
               [spec(()), tok_s(TB), spec((TB,), "i32"), spec(())])
    t_out = (p_out + m_in + v_in + [f32s("step"), f32s("loss")])
    yield ("train_step", train_fn, t_specs, t_in, t_out)

    # --- HDP fine-tuning step (Fig. 11b) -------------------------------------
    def hdp_train_fn(*args):
        (ps, ms, vs), rest = split_params(args, 3)
        step, tokens, labels, lr, rho, tau, qstep = rest
        nps, nms, nvs, nstep, loss = M.hdp_train_step(
            cfg, ps, ms, vs, step, tokens, labels, lr, rho, tau, qstep)
        return tuple(nps) + tuple(nms) + tuple(nvs) + (nstep, loss)
    yield ("hdp_train_step", hdp_train_fn,
           t_specs + [spec(())] * 3,
           t_in + [f32s("rho"), f32s("tau"), f32s("qstep")],
           t_out)

    # --- raw attention unit (rust cross-validation target) -------------------
    def unit_fn(iq, fq, ik, fk, v, rho, tau, inv, use_ff, use_hw):
        return M.hdp_attn_unit(iq, fq, ik, fk, v, rho, tau, inv,
                               use_ff, use_hw)
    hs = spec((H, l, dh))
    yield ("hdp_attn_unit", unit_fn,
           [hs] * 5 + [spec(())] * 5,
           [io("iq", (H, l, dh)), io("fq", (H, l, dh)),
            io("ik", (H, l, dh)), io("fk", (H, l, dh)),
            io("v", (H, l, dh)), f32s("rho"), f32s("tau"),
            f32s("inv_scale"), f32s("use_ff"), f32s("use_hw_softmax")],
           [io("out", (H, l, dh)), io("probs", (H, l, l)),
            io("kept_density", (H,)), io("head_kept", (H,))])


def compile_model(cfg, outdir, force=False):
    entries = {}
    for name, fn, in_specs, in_io, out_io in build_entries(cfg):
        fname = f"{cfg.name}.{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        entries[name] = {"file": fname, "inputs": in_io, "outputs": out_io}
        if os.path.exists(path) and not force:
            print(f"  [skip] {fname}")
            continue
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  [ok]   {fname}  {len(text)/1e6:.2f} MB  {time.time()-t0:.1f}s")
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--models", default="tiny,base")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # Merge into any existing manifest so partial --models runs don't
    # clobber other models' entries.
    mpath0 = os.path.join(args.out, "manifest.json")
    manifest = {"format": 1, "models": {}}
    if os.path.exists(mpath0):
        try:
            with open(mpath0) as f:
                manifest = json.load(f)
        except (json.JSONDecodeError, OSError):
            pass
    for mname in args.models.split(","):
        cfg = MODELS[mname]
        print(f"model {mname}:")
        entries = compile_model(cfg, args.out, args.force)
        manifest["models"][mname] = {
            "config": {
                "vocab_size": cfg.vocab_size, "n_layers": cfg.n_layers,
                "d_model": cfg.d_model, "n_heads": cfg.n_heads,
                "seq_len": cfg.seq_len, "d_ff": cfg.d_ff,
                "n_classes": cfg.n_classes, "d_head": cfg.d_head,
                "train_batch": TRAIN_BATCH, "eval_batch": EVAL_BATCH,
            },
            "params": [{"name": nm, "shape": list(sh)}
                       for nm, sh in cfg.param_shapes()],
            "entries": entries,
        }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
