"""Layer-2: the encoder-only transformer (JAX), every attention variant,
and the AOT-able training step.

All entry points here are pure functions over a *flat list* of parameter
arrays ordered by ``ModelConfig.param_shapes()`` — that ordering is the
interchange contract with the rust parameter store. Pruning knobs
(rho_B, tau_H, quantization step, approximation / hw-softmax flags) are
runtime scalars so a single AOT artifact serves every sweep point of
every figure.

Attention variants:
  dense    — float reference (also the training path for the main
             checkpoints; the paper prunes pre-trained models without
             retraining).
  hdp      — Algorithm 2 through the Layer-1 Pallas kernels.
  topk     — Top-K 2x2 block pruning baseline (Fig. 7).
  spatten  — SpAtten-style cascaded head pruning baseline (Fig. 11a):
             per-example head importance accumulated across layers from
             |attention output|; once pruned, a head stays pruned in all
             subsequent layers.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import hdp_attention as kern
from .kernels import ref

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed):
    """Initialize the flat parameter list from an int32 seed scalar.

    Scaled-normal init for matrices, zeros/ones for biases/LN — standard
    BERT-style init, expressed so it lowers to a single HLO with the seed
    as a runtime input (the rust driver owns seeding).
    """
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        if name.endswith((".g",)):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".b", "b1", "b2", "bqkv", "bo")) or name == "cls.b":
            out.append(jnp.zeros(shape, jnp.float32))
        elif name in ("tok_emb", "pos_emb"):
            out.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
        else:
            fan_in = shape[0]
            out.append(jax.random.normal(sub, shape, jnp.float32)
                       / jnp.sqrt(jnp.float32(fan_in)))
    return out


def _named(cfg: ModelConfig, params):
    names = [n for n, _ in cfg.param_shapes()]
    assert len(names) == len(params), (len(names), len(params))
    return dict(zip(names, params))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _qkv(p, i, x, cfg):
    """Project to per-head Q, K, V: [B, l, d] -> 3 x [B, H, l, d_h]."""
    h = layer_norm(x, p[f"layer{i}.ln1.g"], p[f"layer{i}.ln1.b"])
    qkv = h @ p[f"layer{i}.wqkv"] + p[f"layer{i}.bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    def heads(t):
        b, l, d = t.shape
        return t.reshape(b, l, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    return heads(q), heads(k), heads(v)


def _merge_heads(o):
    """[B, H, l, d_h] -> [B, l, d]."""
    b, h, l, dh = o.shape
    return o.transpose(0, 2, 1, 3).reshape(b, l, h * dh)


def _ffn(p, i, x):
    h = layer_norm(x, p[f"layer{i}.ln2.g"], p[f"layer{i}.ln2.b"])
    h = jax.nn.gelu(h @ p[f"layer{i}.w1"] + p[f"layer{i}.b1"])
    return h @ p[f"layer{i}.w2"] + p[f"layer{i}.b2"]


def _embed(p, tokens):
    return p["tok_emb"][tokens] + p["pos_emb"][None, :, :]


def _head_out(p, cfg, x):
    h = layer_norm(x, p["ln_f.g"], p["ln_f.b"])
    pooled = jnp.mean(h, axis=1)
    return pooled @ p["cls.w"] + p["cls.b"]


def _quant_split(t, qstep):
    """Per-tensor calibrated quantization + int/frac split for a [B,H,l,dh]
    activation. Returns (int_part, frac_part, scale).

    Calibration and rounding sit behind ``stop_gradient``: the forward
    values are the exact fixed-point grid, while gradients use the
    straight-through estimator (round/trunc have zero derivative, which
    would otherwise starve the HDP fine-tuning path of Fig. 11b).
    """
    flat = jnp.sort(jax.lax.stop_gradient(jnp.abs(t)).ravel())
    p = flat[int(0.995 * (flat.shape[0] - 1))]  # 99.5th percentile
    scale = 4.0 / (p + 1e-6)  # target_amax = half the 3-bit integer range
    amax = 8.0 - qstep
    qs = t * scale
    qq = jnp.clip(jnp.round(qs / qstep) * qstep, -amax, amax)
    q = qs + jax.lax.stop_gradient(qq - qs)  # forward: qq; backward: identity
    i = jax.lax.stop_gradient(jnp.trunc(qq))
    return i, q - i, scale


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def dense_forward(cfg, params, tokens, return_probs=False):
    """Float reference forward. Returns logits [B, n_classes]; with
    ``return_probs`` also the attention probabilities [L, B, H, l, l]
    (the Fig. 2 probe)."""
    p = _named(cfg, params)
    x = _embed(p, tokens)
    all_probs = []
    for i in range(cfg.n_layers):
        q, k, v = _qkv(p, i, x, cfg)
        score = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
            jnp.float32(cfg.d_head))
        probs = ref.exact_softmax(score)
        if return_probs:
            all_probs.append(probs)
        o = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        x = x + _merge_heads(o) @ p[f"layer{i}.wo"] + p[f"layer{i}.bo"]
        x = x + _ffn(p, i, x)
    logits = _head_out(p, cfg, x)
    if return_probs:
        return logits, jnp.stack(all_probs)
    return logits


def hdp_forward(cfg, params, tokens, rho, tau, qstep, use_ff, use_hw,
                use_kernel=True):
    """HDP forward. ``use_kernel=True`` routes attention through the
    Layer-1 Pallas kernels (the inference artifacts); ``False`` uses the
    numerically-identical jnp oracle — required for the training path,
    since ``pallas_call`` has no autodiff rule (pytest asserts the two
    paths agree, so the gradients are faithful to the kernels).

    Returns (logits [B, C], kept_density [L, H] mean over batch,
    head_kept [L, H] fraction of examples where the head survived).
    """
    p = _named(cfg, params)
    x = _embed(p, tokens)
    dens_layers, kept_layers = [], []
    for i in range(cfg.n_layers):
        q, k, v = _qkv(p, i, x, cfg)
        iq, fq, sq = _quant_split(q, qstep)
        ik, fk, sk = _quant_split(k, qstep)
        inv = 1.0 / (sq * sk * jnp.sqrt(jnp.float32(cfg.d_head)))
        if use_kernel:
            attn = lambda a, b, c, d, e: kern.hdp_attention(
                a, b, c, d, e, rho, tau, inv, use_ff, use_hw)
        else:
            attn = jax.vmap(  # over heads; batch vmap applied below
                lambda a, b, c, d, e: ref.hdp_head_ref(
                    a, b, c, d, e, rho, tau, inv,
                    use_ff=use_ff, use_hw_softmax=use_hw))
        o, _probs, dens, kept = jax.vmap(attn)(iq, fq, ik, fk, v)
        dens_layers.append(jnp.mean(dens, axis=0))
        kept_layers.append(jnp.mean(kept, axis=0))
        x = x + _merge_heads(o) @ p[f"layer{i}.wo"] + p[f"layer{i}.bo"]
        x = x + _ffn(p, i, x)
    logits = _head_out(p, cfg, x)
    return logits, jnp.stack(dens_layers), jnp.stack(kept_layers)


def topk_forward(cfg, params, tokens, keep_frac, qstep):
    """Top-K block-pruning baseline forward (exact quantized scores)."""
    p = _named(cfg, params)
    x = _embed(p, tokens)
    dens_layers = []
    for i in range(cfg.n_layers):
        q, k, v = _qkv(p, i, x, cfg)
        iq, fq, sq = _quant_split(q, qstep)
        ik, fk, sk = _quant_split(k, qstep)
        inv = 1.0 / (sq * sk * jnp.sqrt(jnp.float32(cfg.d_head)))
        o, _probs, dens = jax.vmap(
            lambda a, b, c, d, e: kern.topk_attention(
                a, b, c, d, e, keep_frac, inv)
        )(iq, fq, ik, fk, v)
        dens_layers.append(jnp.mean(dens, axis=0))
        x = x + _merge_heads(o) @ p[f"layer{i}.wo"] + p[f"layer{i}.bo"]
        x = x + _ffn(p, i, x)
    logits = _head_out(p, cfg, x)
    return logits, jnp.stack(dens_layers)


def spatten_forward(cfg, params, tokens, prune_frac):
    """SpAtten-style cascaded head pruning (Fig. 11a baseline).

    Head importance is accumulated per example across layers as the sum
    of |attention output|; after layer j the schedule targets
    floor(prune_frac * H_total * (j+1)/L) pruned heads, and a pruned head
    never comes back (the cascade the paper criticizes: importance is
    data- AND layer-dependent, so cascading over-prunes).
    Returns (logits, alive [L, H] fraction of examples head alive).
    """
    p = _named(cfg, params)
    x = _embed(p, tokens)
    bsz = tokens.shape[0]
    hh = cfg.n_heads
    alive = jnp.ones((bsz, hh), jnp.float32)
    imp = jnp.zeros((bsz, hh), jnp.float32)
    alive_layers = []
    for i in range(cfg.n_layers):
        q, k, v = _qkv(p, i, x, cfg)
        score = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
            jnp.float32(cfg.d_head))
        probs = ref.exact_softmax(score)
        o = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        o = o * alive[:, :, None, None]
        alive_layers.append(jnp.mean(alive, axis=0))
        imp = imp + jnp.sum(jnp.abs(o), axis=(2, 3))
        # Cascade schedule: by layer i, prune_frac*(i+1)/L of all heads.
        n_prune = jnp.floor(
            prune_frac * hh * (i + 1) / cfg.n_layers).astype(jnp.int32)
        order = jnp.sort(imp, axis=-1)  # ascending
        idx = jnp.clip(n_prune - 1, 0, hh - 1)
        thresh = jnp.take_along_axis(
            order, jnp.broadcast_to(idx, (bsz,))[:, None], axis=-1)
        new_alive = jnp.where(n_prune > 0,
                              (imp > thresh).astype(jnp.float32),
                              jnp.ones_like(alive))
        alive = alive * new_alive  # cascaded: never resurrect
        x = x + _merge_heads(o) @ p[f"layer{i}.wo"] + p[f"layer{i}.bo"]
        x = x + _ffn(p, i, x)
    logits = _head_out(p, cfg, x)
    return logits, jnp.stack(alive_layers)


# ---------------------------------------------------------------------------
# Training (Adam + cross entropy), AOT-able
# ---------------------------------------------------------------------------


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def loss_dense(cfg, params, tokens, labels):
    return _xent(dense_forward(cfg, params, tokens), labels)


def loss_hdp(cfg, params, tokens, labels, rho, tau, qstep):
    logits, _, _ = hdp_forward(cfg, params, tokens, rho, tau, qstep,
                               jnp.float32(0.0), jnp.float32(0.0),
                               use_kernel=False)
    return _xent(logits, labels)


def adam_step(grads, params, m, v, step, lr,
              b1=0.9, b2=0.999, eps=1e-8):
    step = step + 1.0
    new_p, new_m, new_v = [], [], []
    for g, p, mi, vi in zip(grads, params, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * jnp.square(g)
        mhat = mi / (1 - b1 ** step)
        vhat = vi / (1 - b2 ** step)
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, step


def train_step(cfg, params, m, v, step, tokens, labels, lr):
    """One dense-attention Adam step. Returns (params', m', v', step', loss)."""
    loss, grads = jax.value_and_grad(
        lambda ps: loss_dense(cfg, ps, tokens, labels))(params)
    new_p, new_m, new_v, step = adam_step(grads, params, m, v, step, lr)
    return new_p, new_m, new_v, step, loss


def hdp_train_step(cfg, params, m, v, step, tokens, labels, lr,
                   rho, tau, qstep):
    """One Adam step *through the HDP attention path* — the "fine-tuned"
    variant of Fig. 11b (gradients flow through kept scores; the
    mask/threshold comparisons are straight-through-zero)."""
    loss, grads = jax.value_and_grad(
        lambda ps: loss_hdp(cfg, ps, tokens, labels, rho, tau, qstep))(params)
    new_p, new_m, new_v, step = adam_step(grads, params, m, v, step, lr)
    return new_p, new_m, new_v, step, loss


# ---------------------------------------------------------------------------
# Single-head unit entry (rust <-> jax cross-validation)
# ---------------------------------------------------------------------------


def hdp_attn_unit(iq, fq, ik, fk, v, rho, tau, inv_scale, use_ff, use_hw):
    """Raw multi-head HDP attention on pre-split inputs — the artifact the
    rust functional model and cycle simulator validate against."""
    return kern.hdp_attention(iq, fq, ik, fk, v, rho, tau, inv_scale,
                              use_ff, use_hw)
