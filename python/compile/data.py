"""Synthetic GLUE-like datasets, bit-identical to ``rust/src/data/``.

The paper evaluates on SST-2 and CoLA, which (like the pre-trained BERT
checkpoints) are not available in this sandbox. These generators build
the closest synthetic equivalents (DESIGN.md §Substitutions):

* ``sst2s`` — sentiment-like: a handful of polarity-bearing "lexicon"
  tokens decide the label (with negation tokens that flip the next
  lexicon token). Mirrors SST-2's property that a few key tokens carry
  the signal, which is exactly what makes attention prunable.
* ``colas`` — acceptability-like: the label is whether the sequence's
  bracket tokens are properly matched and nested. A global structural
  judgement, like CoLA; harder, so pruning headroom is lower.

Both python and rust implement the same splitmix64 PRNG and the same
sampling algorithm so that the training set the rust driver streams
through PJRT equals the one pytest validates. ``python/tests/test_data.py``
and ``rust/src/data/mod.rs`` pin identical golden vectors.
"""

from dataclasses import dataclass
from typing import List, Tuple

MASK64 = (1 << 64) - 1

# ---------------------------------------------------------------------------
# splitmix64 — the shared cross-language PRNG.
# ---------------------------------------------------------------------------


class SplitMix64:
    """splitmix64 (Steele et al.) — tiny, seedable, cross-language."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def next_below(self, n: int) -> int:
        """Uniform integer in [0, n) via 128-bit multiply (Lemire, biased
        by < 2^-64 — fine for data generation, and trivially portable)."""
        return ((self.next_u64() * n) >> 64) & MASK64

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53 bits."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))


# ---------------------------------------------------------------------------
# Token-space layout (shared constants; rust mirrors these).
# ---------------------------------------------------------------------------

PAD = 0
POS_LO, POS_HI = 10, 19  # positive lexicon (inclusive)
NEG_LO, NEG_HI = 20, 29  # negative lexicon
FLIP_LO, FLIP_HI = 30, 31  # negation: flips polarity of next lexicon token
OPEN_LO, OPEN_HI = 40, 43  # bracket opens; close = open + 4
CLOSE_LO, CLOSE_HI = 44, 47
FILLER_LO = 48  # filler/distractor tokens occupy [FILLER_LO, vocab)

P_LEXICON = 0.15
P_FLIP = 0.05


@dataclass(frozen=True)
class Example:
    tokens: List[int]
    label: int


def _gen_sst2s(rng: SplitMix64, seq_len: int, vocab: int) -> Example:
    """One sentiment-like example. Score = Σ ±1 over lexicon tokens
    (sign flipped when the previous token is a negation); label = score>0.
    Zero scores are broken by overwriting one filler with a lexicon token.
    """
    toks = [0] * seq_len
    for i in range(seq_len):
        r = rng.next_f64()
        if r < P_LEXICON:
            if rng.next_below(2) == 0:
                toks[i] = POS_LO + rng.next_below(POS_HI - POS_LO + 1)
            else:
                toks[i] = NEG_LO + rng.next_below(NEG_HI - NEG_LO + 1)
        elif r < P_LEXICON + P_FLIP:
            toks[i] = FLIP_LO + rng.next_below(FLIP_HI - FLIP_LO + 1)
        else:
            toks[i] = FILLER_LO + rng.next_below(vocab - FILLER_LO)
    score = _sst2s_score(toks)
    if score == 0:
        # Force a decisive token over some filler position (first filler).
        want_pos = rng.next_below(2) == 0
        tok = (POS_LO + rng.next_below(POS_HI - POS_LO + 1)) if want_pos else (
            NEG_LO + rng.next_below(NEG_HI - NEG_LO + 1))
        for i in range(seq_len):
            if toks[i] >= FILLER_LO:
                toks[i] = tok
                break
        score = _sst2s_score(toks)
    return Example(toks, 1 if score > 0 else 0)


def _sst2s_score(toks: List[int]) -> int:
    score = 0
    for i, t in enumerate(toks):
        flipped = i > 0 and FLIP_LO <= toks[i - 1] <= FLIP_HI
        if POS_LO <= t <= POS_HI:
            score += -1 if flipped else 1
        elif NEG_LO <= t <= NEG_HI:
            score += 1 if flipped else -1
    return score


def _gen_colas(rng: SplitMix64, seq_len: int, vocab: int) -> Example:
    """One acceptability-like example: balanced-bracket grammar.

    Positives: a random properly nested bracket string (depth ≤ 4, 4
    bracket kinds) interleaved with fillers. Negatives: same, then one
    corruption (mismatched kind, orphaned close, or swapped pair).
    """
    label = int(rng.next_below(2))
    toks = [0] * seq_len
    stack: List[int] = []
    bracket_pos: List[int] = []
    for i in range(seq_len):
        remaining = seq_len - i
        # Must close everything before running out of room.
        must_close = len(stack) >= remaining
        r = rng.next_f64()
        if must_close or (stack and r < 0.18):
            kind = stack.pop()
            toks[i] = CLOSE_LO + kind
            bracket_pos.append(i)
        elif len(stack) < 4 and r < 0.36:
            kind = int(rng.next_below(4))
            stack.append(kind)
            toks[i] = OPEN_LO + kind
            bracket_pos.append(i)
        else:
            toks[i] = FILLER_LO + rng.next_below(vocab - FILLER_LO)
    # stack is empty by construction (must_close forces closure).
    if label == 0 and bracket_pos:
        j = bracket_pos[rng.next_below(len(bracket_pos))]
        t = toks[j]
        mode = rng.next_below(3)
        if mode == 0:
            # Change bracket kind (mismatch).
            if OPEN_LO <= t <= OPEN_HI:
                toks[j] = OPEN_LO + ((t - OPEN_LO + 1 + rng.next_below(3)) % 4)
            else:
                toks[j] = CLOSE_LO + ((t - CLOSE_LO + 1 + rng.next_below(3)) % 4)
        elif mode == 1:
            # Flip open <-> close (orphans a bracket).
            toks[j] = t + 4 if t <= OPEN_HI else t - 4
        else:
            # Overwrite with filler (drops one side of a pair).
            toks[j] = FILLER_LO + rng.next_below(vocab - FILLER_LO)
        if _colas_wellformed(toks):
            # Corruption can accidentally stay well-formed (e.g. "()"->
            # "[]" relabels a whole pair only if both sides changed —
            # single-site edits rarely do, but overwriting a lone pair's
            # open AND having no close is always caught; the residual
            # case is overwriting when brackets elsewhere still match).
            # Force a guaranteed corruption: orphan close at position 0.
            toks[0] = CLOSE_LO + rng.next_below(4)
    if label == 1 and not bracket_pos:
        pass  # vacuously well-formed
    return Example(toks, 1 if _colas_wellformed(toks) else 0)


def _colas_wellformed(toks: List[int]) -> bool:
    stack: List[int] = []
    for t in toks:
        if OPEN_LO <= t <= OPEN_HI:
            stack.append(t - OPEN_LO)
        elif CLOSE_LO <= t <= CLOSE_HI:
            if not stack or stack.pop() != t - CLOSE_LO:
                return False
    return not stack


GENERATORS = {"sst2s": _gen_sst2s, "colas": _gen_colas}


def generate(dataset: str, split: str, n: int, seq_len: int,
             vocab: int = 256, seed: int = 42) -> Tuple[List[List[int]], List[int]]:
    """Deterministic dataset: stream n examples for (dataset, split, seed).

    The per-split stream seed mixes the base seed with a split tag so
    train/eval never overlap. Rust uses the identical derivation.
    """
    split_tag = {"train": 0x7472, "eval": 0x6576, "probe": 0x7072}[split]
    rng = SplitMix64((seed * 0x9E3779B97F4A7C15 + split_tag) & MASK64)
    gen = GENERATORS[dataset]
    xs, ys = [], []
    for _ in range(n):
        ex = gen(rng, seq_len, vocab)
        xs.append(ex.tokens)
        ys.append(ex.label)
    return xs, ys
