"""Pure-jnp oracle for the HDP kernels — a direct transcription of the
paper's Algorithm 2 (block pruning, early head pruning, approximation)
plus the fixed-point front end and the hardware softmax numerics.

Everything here is the *correctness* reference: the Pallas kernels in
``hdp_attention.py`` must match these functions bit-for-bit on the
pre-softmax path (all quantities are exact in f32 — see DESIGN.md
§Numerics) and to tight tolerance after softmax. The rust functional
model (rust/src/attention/hdp.rs) and the cycle simulator cross-validate
against AOT'd wrappers of these same functions.
"""

import jax.numpy as jnp

NEG_INF = -1e9  # pruned scores are excluded from softmax (finite to keep
# fully-pruned rows NaN-free; they then degrade to uniform attention)


# ---------------------------------------------------------------------------
# Fixed point: quantize + integer/fraction split
# ---------------------------------------------------------------------------

def calibrate_scale(x, qc, eps=1e-6):
    """Per-tensor calibration: map the 99.5th percentile of |x| onto
    ``qc.target_amax`` (half the integer range). Returns a scalar scale
    ``s`` such that ``x * s`` is quantized. Mirrors the paper's host
    quantizer (§IV: Q/K/V arrive pre-quantized in 16-bit fixed point)."""
    flat = jnp.sort(jnp.abs(x).ravel())
    p = flat[int(0.995 * (flat.shape[0] - 1))]  # 99.5th percentile
    return qc.target_amax / (p + eps)


def quantize(x, scale, qc):
    """Scale then round-to-nearest onto the Q(int,frac) grid, saturating."""
    step = 2.0 ** (-qc.frac_bits)
    q = jnp.round(x * scale / step) * step
    return jnp.clip(q, -qc.amax, qc.amax)


def split_int_frac(q):
    """q == i + f with i integer-valued, |f| < 1, sign(f) matching q
    (truncation toward zero — the hardware splits the two's-complement
    fields, which for our symmetric range behaves like trunc)."""
    i = jnp.trunc(q)
    return i, q - i


# ---------------------------------------------------------------------------
# Algorithm 2 pieces
# ---------------------------------------------------------------------------

def block_importance(int_score, block=2):
    """theta: absolute sum over each (block x block) tile of the integer
    score matrix. [..., l, l] -> [..., l/b, l/b]."""
    *lead, l, l2 = int_score.shape
    nb, nb2 = l // block, l2 // block
    t = int_score.reshape(*lead, nb, block, nb2, block)
    return jnp.sum(jnp.abs(t), axis=(-3, -1))


def row_threshold(theta, rho):
    """Theta_i per block-row (Algorithm 2, line 15):

        rho in [0, 1):   Theta =  rho*max + (1-rho)*mean
        rho in (-1, 0):  Theta = -rho*min + (1+rho)*mean

    ``rho`` may be a traced scalar; both branches are computed and
    selected so the expression stays jittable with runtime rho."""
    mn = jnp.min(theta, axis=-1, keepdims=True)
    mx = jnp.max(theta, axis=-1, keepdims=True)
    mean = jnp.mean(theta, axis=-1, keepdims=True)
    pos = rho * mx + (1.0 - rho) * mean
    neg = -rho * mn + (1.0 + rho) * mean
    return jnp.where(rho >= 0.0, pos, neg)


def block_mask(theta, rho):
    """1 for kept blocks (theta >= Theta), 0 for pruned."""
    return (theta >= row_threshold(theta, rho)).astype(jnp.float32)


def expand_mask(mask, block=2):
    """[..., nb, nb] block mask -> [..., l, l] element mask."""
    m = jnp.repeat(mask, block, axis=-1)
    return jnp.repeat(m, block, axis=-2)


# ---------------------------------------------------------------------------
# Hardware softmax (paper §IV-E): 2nd-order polynomial exponent +
# linear-approximation reciprocal.
# ---------------------------------------------------------------------------

LOG2E = 1.4426950408889634
# Quadratic fit for 2^r on r in [0, 1) (max rel err ~1e-2).
_P2 = (0.3371894346, 0.6576362914, 1.0017247597)


def hw_exp(x):
    """e^x ~= 2^(x*log2e); integer part exact via exp2, fraction via poly2."""
    y = x * LOG2E
    n = jnp.floor(y)
    r = y - n
    p = (_P2[0] * r + _P2[1]) * r + _P2[2]
    return p * jnp.exp2(n)


def hw_reciprocal(x):
    """1/x for x > 0: frexp-normalize the mantissa m into [0.5, 1), seed
    with the minimax linear approximation 1/m ~= 48/17 - 32/17 m, then
    one hardware-friendly Newton step (two mults + one sub)."""
    m, e = jnp.frexp(x)  # x = m * 2^e, m in [0.5, 1)
    r = 48.0 / 17.0 - (32.0 / 17.0) * m
    r = r * (2.0 - m * r)
    return jnp.ldexp(r, -e)


def hw_softmax(scores, axis=-1):
    """Row-wise softmax built from the co-processor's approximate units."""
    s = scores - jnp.max(scores, axis=axis, keepdims=True)
    e = hw_exp(s)
    return e * hw_reciprocal(jnp.sum(e, axis=axis, keepdims=True))


def exact_softmax(scores, axis=-1):
    s = scores - jnp.max(scores, axis=axis, keepdims=True)
    e = jnp.exp(s)
    return e / jnp.sum(e, axis=axis, keepdims=True)


# ---------------------------------------------------------------------------
# Full single-head HDP attention (Algorithm 2)
# ---------------------------------------------------------------------------

def hdp_head_ref(iq, fq, ik, fk, v, rho, tau, inv_scale, use_ff=0.0,
                 use_hw_softmax=0.0, block=2):
    """One attention head through Algorithm 2.

    Args:
      iq, fq: integer / fractional parts of quantized Q_h, [l, d_h].
      ik, fk: same for K_h.
      v: value matrix [l, d_h] (float; the functional model keeps V in
         full precision — the simulator studies V quantization separately).
      rho: block pruning ratio rho_B in (-1, 1), runtime scalar.
      tau: head pruning threshold tau_H (compared against theta_head),
           runtime scalar.
      inv_scale: 1 / (s_q * s_k * sqrt(d_head)) — undoes quantization
           scaling and applies the attention temperature in one multiply.
      use_ff: 1.0 adds the FQ.FK term back (exact product — the
           "without approximation" arm of Fig. 9); 0.0 drops it (HDP).
      use_hw_softmax: 1.0 routes through the polynomial softmax unit.

    Returns (out [l, d_h], probs [l, l], kept_density scalar,
             head_kept scalar in {0.,1.}).
    """
    int_score = iq @ ik.T
    theta = block_importance(int_score, block)
    theta_head = jnp.sum(theta)
    mask_b = block_mask(theta, rho)
    head_kept = (theta_head > tau).astype(jnp.float32)

    f1 = iq @ fk.T
    f2 = fq @ ik.T
    ff = fq @ fk.T
    score_q = int_score + f1 + f2 + use_ff * ff
    score = score_q * inv_scale

    mask_el = expand_mask(mask_b, block)
    score = jnp.where(mask_el > 0.0, score, NEG_INF)

    probs = jnp.where(
        use_hw_softmax > 0.0, hw_softmax(score), exact_softmax(score)
    )
    out = (probs @ v) * head_kept
    kept_density = jnp.mean(mask_b)
    return out, probs, kept_density, head_kept


def topk_head_ref(iq, fq, ik, fk, v, keep_frac, inv_scale,
                  use_hw_softmax=0.0, block=2):
    """Top-K 2x2 block pruning baseline (paper Fig. 7 comparator).

    Keeps the ceil(keep_frac * nb) most-important blocks per block-row,
    using the same integer-product importance. keep_frac is a runtime
    scalar, so the cut is a threshold at the k-th order statistic (ties
    keep slightly more — the measured ratio is reported, not the target).
    Kept blocks use the exact quantized product (the paper's Top-K is
    pruning-only, no approximation)."""
    int_score = iq @ ik.T
    theta = block_importance(int_score, block)
    nb = theta.shape[-1]
    order = jnp.sort(theta, axis=-1)[..., ::-1]  # descending
    k = jnp.clip(jnp.ceil(keep_frac * nb) - 1.0, 0.0, nb - 1.0)
    k = k.astype(jnp.int32)
    kth = jnp.take_along_axis(
        order, jnp.broadcast_to(k, theta.shape[:-1])[..., None], axis=-1
    )
    mask_b = (theta >= kth).astype(jnp.float32)

    score = (int_score + iq @ fk.T + fq @ ik.T + fq @ fk.T) * inv_scale
    score = jnp.where(expand_mask(mask_b, block) > 0.0, score, NEG_INF)
    probs = jnp.where(
        use_hw_softmax > 0.0, hw_softmax(score), exact_softmax(score)
    )
    return probs @ v, probs, jnp.mean(mask_b)


def dense_head_ref(q, k, v):
    """Float reference attention (no quantization, no pruning)."""
    score = (q @ k.T) / jnp.sqrt(jnp.float32(q.shape[-1]))
    probs = exact_softmax(score)
    return probs @ v, probs
