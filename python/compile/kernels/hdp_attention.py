"""Layer-1 Pallas kernels for HDP attention.

The co-processor's per-head pipeline (paper Fig. 4) maps onto Pallas as:

* grid = (H,) — HDP processes attention heads sequentially (§IV-A
  "HDP processes each attention head sequentially"); each grid step is
  one head resident in VMEM.
* The PE array's output-stationary tiled matmul becomes the in-VMEM
  ``iq @ ik.T`` with the 2x2 block-importance reduction fused on the
  accumulator outputs (the importance tap on the PE accumulators in
  Fig. 4 right).
* The Sparsity Engine's per-block-row min/max/mean -> Theta -> mask is
  straight-line jnp on the theta tile.
* FUM (fetch-upon-mask) becomes masking of the fractional products; the
  DRAM-traffic consequence is modeled by the rust cycle simulator, the
  numerics are bit-exact here.

``interpret=True`` everywhere: the kernels lower to plain HLO so the
rust PJRT CPU client can execute the AOT artifacts (real-TPU Mosaic
custom-calls cannot run on CPU — see DESIGN.md §Hardware-Adaptation for
the VMEM/MXU discussion).

The kernel bodies call the *same* jnp helpers as the oracle in
``ref.py``, so kernel-vs-ref equality (checked by pytest/hypothesis)
validates the Pallas plumbing (grids, BlockSpecs, scalar broadcast)
rather than re-derived math.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# ---------------------------------------------------------------------------
# Fused per-head HDP attention kernel (Algorithm 2 end to end)
# ---------------------------------------------------------------------------


def _hdp_kernel(rho_ref, tau_ref, inv_ref, useff_ref, usehw_ref,
                iq_ref, fq_ref, ik_ref, fk_ref, v_ref,
                out_ref, probs_ref, dens_ref, kept_ref, *, block):
    out, probs, dens, kept = ref.hdp_head_ref(
        iq_ref[0], fq_ref[0], ik_ref[0], fk_ref[0], v_ref[0],
        rho_ref[0], tau_ref[0], inv_ref[0],
        use_ff=useff_ref[0], use_hw_softmax=usehw_ref[0], block=block,
    )
    out_ref[0] = out
    probs_ref[0] = probs
    dens_ref[0] = dens
    kept_ref[0] = kept


def hdp_attention(iq, fq, ik, fk, v, rho, tau, inv_scale,
                  use_ff, use_hw_softmax, *, block=2):
    """Multi-head HDP attention via the fused Pallas kernel.

    Args:
      iq, fq, ik, fk: integer/fraction parts of quantized Q/K, [H, l, d_h].
      v: [H, l, d_h] float values.
      rho, tau, inv_scale, use_ff, use_hw_softmax: runtime scalars
        (python floats or traced 0-d arrays).

    Returns (out [H, l, d_h], probs [H, l, l], kept_density [H],
             head_kept [H]).
    """
    h, l, dh = iq.shape
    scal = lambda x: jnp.asarray(x, jnp.float32).reshape(1)
    sspec = pl.BlockSpec((1,), lambda i: (0,))
    hspec = pl.BlockSpec((1, l, dh), lambda i: (i, 0, 0))
    pspec = pl.BlockSpec((1, l, l), lambda i: (i, 0, 0))
    vspec = pl.BlockSpec((1,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_hdp_kernel, block=block),
        grid=(h,),
        in_specs=[sspec] * 5 + [hspec] * 5,
        out_specs=[hspec, pspec, vspec, vspec],
        out_shape=[
            jax.ShapeDtypeStruct((h, l, dh), jnp.float32),
            jax.ShapeDtypeStruct((h, l, l), jnp.float32),
            jax.ShapeDtypeStruct((h,), jnp.float32),
            jax.ShapeDtypeStruct((h,), jnp.float32),
        ],
        interpret=True,
    )(scal(rho), scal(tau), scal(inv_scale), scal(use_ff),
      scal(use_hw_softmax), iq, fq, ik, fk, v)


# ---------------------------------------------------------------------------
# Integer-score + block-importance kernel (the PE-array stage alone).
# Used by tests and by the fig2-style probes; mirrors the first pipeline
# stage of the co-processor before the Sparsity Engine decides anything.
# ---------------------------------------------------------------------------


def _int_score_kernel(iq_ref, ik_ref, score_ref, theta_ref, *, block):
    int_score = iq_ref[0] @ ik_ref[0].T
    score_ref[0] = int_score
    theta_ref[0] = ref.block_importance(int_score, block)


def int_score_theta(iq, ik, *, block=2):
    """[H, l, d_h] x2 -> (int_score [H, l, l], theta [H, l/b, l/b])."""
    h, l, dh = iq.shape
    nb = l // block
    hspec = pl.BlockSpec((1, l, dh), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_int_score_kernel, block=block),
        grid=(h,),
        in_specs=[hspec, hspec],
        out_specs=[
            pl.BlockSpec((1, l, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, nb, nb), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, l, l), jnp.float32),
            jax.ShapeDtypeStruct((h, nb, nb), jnp.float32),
        ],
        interpret=True,
    )(iq, ik)


# ---------------------------------------------------------------------------
# Top-K baseline kernel (Fig. 7 comparator)
# ---------------------------------------------------------------------------


def _topk_kernel(keep_ref, inv_ref, usehw_ref,
                 iq_ref, fq_ref, ik_ref, fk_ref, v_ref,
                 out_ref, probs_ref, dens_ref, *, block):
    out, probs, dens = ref.topk_head_ref(
        iq_ref[0], fq_ref[0], ik_ref[0], fk_ref[0], v_ref[0],
        keep_ref[0], inv_ref[0], use_hw_softmax=usehw_ref[0], block=block,
    )
    out_ref[0] = out
    probs_ref[0] = probs
    dens_ref[0] = dens


def topk_attention(iq, fq, ik, fk, v, keep_frac, inv_scale,
                   use_hw_softmax=0.0, *, block=2):
    """Multi-head Top-K block-pruned attention. Same contract as
    :func:`hdp_attention` minus head pruning / approximation knobs."""
    h, l, dh = iq.shape
    scal = lambda x: jnp.asarray(x, jnp.float32).reshape(1)
    sspec = pl.BlockSpec((1,), lambda i: (0,))
    hspec = pl.BlockSpec((1, l, dh), lambda i: (i, 0, 0))
    pspec = pl.BlockSpec((1, l, l), lambda i: (i, 0, 0))
    vspec = pl.BlockSpec((1,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_topk_kernel, block=block),
        grid=(h,),
        in_specs=[sspec] * 3 + [hspec] * 5,
        out_specs=[hspec, pspec, vspec],
        out_shape=[
            jax.ShapeDtypeStruct((h, l, dh), jnp.float32),
            jax.ShapeDtypeStruct((h, l, l), jnp.float32),
            jax.ShapeDtypeStruct((h,), jnp.float32),
        ],
        interpret=True,
    )(scal(keep_frac), scal(inv_scale), scal(use_hw_softmax),
      iq, fq, ik, fk, v)


# ---------------------------------------------------------------------------
# Hardware softmax as a standalone kernel (softmax-unit ablation)
# ---------------------------------------------------------------------------


def _hw_softmax_kernel(x_ref, o_ref):
    o_ref[...] = ref.hw_softmax(x_ref[...])


def hw_softmax(x):
    """Row-wise polynomial softmax over the last axis of a 2-D array."""
    return pl.pallas_call(
        _hw_softmax_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x)
