"""Model / quantization configurations shared across the compile path.

Two encoder geometries stand in for the paper's evaluation models (see
DESIGN.md §Substitutions):

* ``tiny``  — BERT-Tiny's exact shape: 2 layers, d=128, 2 heads (d_h=64).
* ``base``  — a scaled BERT-Base: 4 layers, d=256, 8 heads (d_h=32),
  keeping the "many heads" regime (32 heads total) that gives the paper
  its 13-17% head-pruning headroom.

The quantization profiles model the co-processor's host interface: Q/K/V
arrive in fixed point (paper §IV: "quantized by another processor in
fixed point 16 bit format"). ``q4_12`` is the 16-bit profile used for the
main results; ``q4_8`` is the 12-bit profile used for the SpAtten
comparison (paper §V-B).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class QuantConfig:
    """Fixed-point profile for the HDP integer/fraction decomposition."""

    name: str
    int_bits: int  # integer bits excluding sign
    frac_bits: int

    @property
    def total_bits(self) -> int:
        return self.int_bits + self.frac_bits + 1  # + sign

    @property
    def amax(self) -> float:
        """Largest representable magnitude."""
        return float(2**self.int_bits) - 2.0**-self.frac_bits

    @property
    def target_amax(self) -> float:
        """Calibration point: 99.5th-percentile |x| maps here.

        Half the integer range, so integer parts carry the bulk of the
        signal while headroom absorbs the tail above the percentile.
        """
        return float(2**self.int_bits) / 2.0


Q4_12 = QuantConfig("q4_12", int_bits=3, frac_bits=12)  # 16-bit
Q4_8 = QuantConfig("q4_8", int_bits=3, frac_bits=8)  # 12-bit

QUANTS = {q.name: q for q in (Q4_12, Q4_8)}


@dataclass(frozen=True)
class ModelConfig:
    """Encoder-only transformer geometry."""

    name: str
    vocab_size: int
    n_layers: int
    d_model: int
    n_heads: int
    seq_len: int
    d_ff: int
    n_classes: int = 2

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_blocks_per_row(self) -> int:
        """Number of 2x2 blocks along one side of the l x l score matrix."""
        assert self.seq_len % 2 == 0
        return self.seq_len // 2

    def param_shapes(self):
        """Ordered (name, shape) list — the AOT/rust interchange contract.

        The rust parameter store (rust/src/model/params.rs) indexes
        parameters by position in this list; keep it append-only.
        """
        d, f, v, l = self.d_model, self.d_ff, self.vocab_size, self.seq_len
        shapes = [
            ("tok_emb", (v, d)),
            ("pos_emb", (l, d)),
        ]
        for i in range(self.n_layers):
            p = f"layer{i}."
            shapes += [
                (p + "ln1.g", (d,)),
                (p + "ln1.b", (d,)),
                (p + "wqkv", (d, 3 * d)),
                (p + "bqkv", (3 * d,)),
                (p + "wo", (d, d)),
                (p + "bo", (d,)),
                (p + "ln2.g", (d,)),
                (p + "ln2.b", (d,)),
                (p + "w1", (d, f)),
                (p + "b1", (f,)),
                (p + "w2", (f, d)),
                (p + "b2", (d,)),
            ]
        shapes += [
            ("ln_f.g", (d,)),
            ("ln_f.b", (d,)),
            ("cls.w", (d, self.n_classes)),
            ("cls.b", (self.n_classes,)),
        ]
        return shapes


TINY = ModelConfig(
    name="tiny", vocab_size=256, n_layers=2, d_model=128, n_heads=2,
    seq_len=64, d_ff=256,
)
BASE = ModelConfig(
    name="base", vocab_size=256, n_layers=4, d_model=256, n_heads=8,
    seq_len=128, d_ff=512,
)

MODELS = {m.name: m for m in (TINY, BASE)}

# Batch sizes baked into the AOT artifacts (PJRT executables have static
# shapes; the rust batcher pads up to these).
TRAIN_BATCH = 32
EVAL_BATCH = 32
